"""Comparison algorithms (§V-F1), adapted to the disjoint FSSL scenario the
same way the paper adapts them: the server's supervised model joins each
global update with the dynamic supervised weight.

* FedAvg-SSL-Partial — 6 pre-selected clients per round, synchronous
* FedAvg-SSL-All     — all clients per round, synchronous
* FedAsync-SSL       — aggregate on every single arrival (FedAsync mixing,
                       polynomial staleness, forced sync past staleness 16)
* Local-SSL          — centralized semi-supervised ceiling
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.feds3a_cnn import CONFIG as CNN_CONFIG
from repro.core import aggregation as agg
from repro.core.feds3a import FedS3AConfig
from repro.core.functions import supervised_weight
from repro.core.metrics import weighted_metrics
from repro.core.pseudo_label import (make_client_epoch, make_server_epoch,
                                     predict_fn)
from repro.core.scheduler import paper_latency
from repro.models.cnn import init_cnn
from repro.optimizer import adam_init


class _Base:
    def __init__(self, data, config: FedS3AConfig | None = None):
        self.cfg = config or FedS3AConfig()
        self.data = data
        self.M = len(data["clients"])
        self.cnn = CNN_CONFIG
        self.rng = jax.random.PRNGKey(self.cfg.seed)
        self.client_epoch = make_client_epoch(
            self.cnn, batch_size=self.cfg.batch_size,
            threshold=self.cfg.threshold, l1=self.cfg.l1)
        self.server_epoch = make_server_epoch(
            self.cnn, batch_size=self.cfg.batch_size, l1=self.cfg.l1)
        self.predict = predict_fn(self.cnn)
        sizes = [len(c["x"]) for c in data["clients"]]
        ref_total = 453004
        f = ref_total / max(sum(sizes), 1)
        self.latencies = [paper_latency(int(s * f)) for s in sizes]
        self.np_rng = np.random.default_rng(self.cfg.seed)

        self.rng, k = jax.random.split(self.rng)
        params = init_cnn(self.cnn, k)
        opt = adam_init(params)
        for _ in range(self.cfg.init_server_epochs):
            self.rng, k = jax.random.split(self.rng)
            params, opt, _ = self.server_epoch(
                params, opt, data["server"]["x"], data["server"]["y"],
                self.cfg.lr, k)
        self.global_params = params
        self.server_opt = opt
        self.comm_bytes = 0
        self.dense_bytes = 0

    def _count_comm(self, n_msgs):
        n = sum(l.size for l in jax.tree.leaves(self.global_params))
        self.comm_bytes += n_msgs * n * 4
        self.dense_bytes += n_msgs * n * 4

    def _train_client(self, i, params, lr):
        self.rng, k = jax.random.split(self.rng)
        x = self.data["clients"][i]["x"]
        opt = adam_init(params)
        for e in range(self.cfg.epochs):
            # every epoch gets its own derived key (epoch 0 keeps the raw
            # split so single-epoch runs are bit-identical to before);
            # reusing one key replays the same batch shuffle and dropout
            # mask each epoch — the multi-epoch bug FedS3A's engines fixed
            ke = k if e == 0 else jax.random.fold_in(k, e)
            params, opt, _ = self.client_epoch(params, opt, x, lr, ke)
        return params

    def _server_step(self):
        self.rng, k = jax.random.split(self.rng)
        sp, self.server_opt, _ = self.server_epoch(
            self.global_params, self.server_opt,
            self.data["server"]["x"], self.data["server"]["y"], self.cfg.lr, k)
        return sp

    def evaluate(self):
        test = self.data["test"]
        preds = np.asarray(self.predict(self.global_params, jnp.asarray(test["x"])))
        return weighted_metrics(test["y"], preds, self.cnn.num_classes)

    @property
    def aco(self):
        # empty ledger reads 0.0, matching SparseComm.aco: nothing crossed
        # the wire, so the overhead ratio is zero (not a free full model)
        return self.comm_bytes / self.dense_bytes if self.dense_bytes else 0.0


class FedAvgSSL(_Base):
    """Synchronous FedAvg adapted to FSSL. mode: 'partial' (6 clients) / 'all'."""

    def __init__(self, data, config=None, *, mode="partial", per_round=6):
        super().__init__(data, config)
        self.mode = mode
        self.per_round = per_round if mode == "partial" else self.M

    def train(self, rounds=None):
        rounds = rounds or self.cfg.rounds
        arts = []
        for r in range(rounds):
            sel = (self.np_rng.choice(self.M, self.per_round, replace=False)
                   if self.mode == "partial" else np.arange(self.M))
            models, sizes = [], []
            for i in sel:
                models.append(self._train_client(i, self.global_params, self.cfg.lr))
                sizes.append(len(self.data["clients"][i]["x"]))
            sp = self._server_step()
            fw = supervised_weight(r, C=self.per_round / self.M, M=self.M,
                                   mode=self.cfg.supervised_weight_mode)
            self.global_params = agg.fedavg_ssl(sp, models, sizes, fw)
            self._count_comm(2 * len(sel))
            arts.append(max(self.latencies[i] for i in sel))
        return {"metrics": self.evaluate(), "art": float(np.mean(arts)),
                "aco": self.aco, "rounds": rounds}


class FedAsyncSSL(_Base):
    """FedAsync [23] adapted to FSSL: update on every arrival."""

    def __init__(self, data, config=None, *, alpha=0.9, a=0.5, max_stale=16):
        super().__init__(data, config)
        self.alpha = alpha
        self.a = a
        self.max_stale = max_stale
        self.forced_syncs = 0

    def train(self, rounds=None):
        rounds = rounds or self.cfg.rounds
        # event loop: every client trains continuously; each arrival = round
        heap = []
        version = {i: 0 for i in range(self.M)}
        base = {i: self.global_params for i in range(self.M)}
        t = 0.0
        for i in range(self.M):
            heapq.heappush(heap, (self.latencies[i], i))
        times = []
        g_version = 0
        prev_t = 0.0
        r = 0
        while r < rounds:
            t, i = heapq.heappop(heap)
            s = g_version - version[i]
            if s > self.max_stale:
                # forced sync (the paper's staleness guard): the run this
                # client would report is too stale to blend. The old code
                # trained anyway, silently dropped the upload, yet booked a
                # full round-trip, advanced g_version and consumed a round
                # — inflating ACO with bytes that bought nothing and
                # recording an aggregation that never happened. Only the
                # fresh model actually crosses the wire (one downlink); the
                # client restarts from it and the round is not consumed.
                version[i] = g_version
                base[i] = self.global_params
                self._count_comm(1)
                self.forced_syncs += 1
                heapq.heappush(heap, (t + self.latencies[i], i))
                continue
            newp = self._train_client(i, base[i], self.cfg.lr)
            sp = self._server_step()
            fw = supervised_weight(r, C=1 / self.M, M=self.M,
                                   mode=self.cfg.supervised_weight_mode)
            blended = agg.fedasync_blend(self.global_params, newp,
                                         staleness=s, alpha=self.alpha,
                                         a=self.a)
            self.global_params = jax.tree.map(
                lambda spv, bv: (fw * spv.astype(jnp.float32) +
                                 (1 - fw) * bv.astype(jnp.float32)
                                 ).astype(spv.dtype), sp, blended)
            g_version += 1
            version[i] = g_version
            base[i] = self.global_params
            self._count_comm(2)
            heapq.heappush(heap, (t + self.latencies[i], i))
            times.append(t - prev_t)
            prev_t = t
            r += 1
        return {"metrics": self.evaluate(), "art": float(np.mean(times)),
                "aco": self.aco, "rounds": rounds,
                "forced_syncs": self.forced_syncs}


class LocalSSL(_Base):
    """Centralized semi-supervised ceiling: labeled server data + pooled
    unlabeled client data, FixMatch-style pseudo-label training."""

    def train(self, rounds=None):
        rounds = rounds or self.cfg.rounds
        x_all = np.concatenate([c["x"] for c in self.data["clients"]])
        params, opt = self.global_params, adam_init(self.global_params)
        uopt = adam_init(params)
        for r in range(rounds):
            self.rng, k1 = jax.random.split(self.rng)
            params, opt, _ = self.server_epoch(
                params, opt, self.data["server"]["x"],
                self.data["server"]["y"], self.cfg.lr, k1)
            self.rng, k2 = jax.random.split(self.rng)
            params, uopt, _ = self.client_epoch(params, uopt, x_all,
                                                self.cfg.lr, k2)
        self.global_params = params
        return {"metrics": self.evaluate(), "art": float("nan"),
                "aco": float("nan"), "rounds": rounds}
