"""The FedS3A trainer: ties together the semi-async scheduler, FSSL training,
group-based staleness-weighted aggregation, adaptive learning rates and
sparse-difference communication. Reproduces the paper's Tables V-XII.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.feds3a_cnn import CONFIG as CNN_CONFIG
from repro.core import aggregation as agg
from repro.core.functions import (adaptive_learning_rates, round_weight_fn,
                                  staleness_fn, supervised_weight)
from repro.core.grouping import group_clients
from repro.core.metrics import weighted_metrics
from repro.core.pseudo_label import (class_histogram, make_client_epoch,
                                     make_server_epoch, predict_fn)
from repro.core.scheduler import SemiAsyncScheduler, paper_latency
from repro.core.sparse_comm import SparseComm
from repro.models.cnn import init_cnn
from repro.optimizer import adam_init


@dataclass
class FedS3AConfig:
    rounds: int = 20
    C: float = 0.6                      # participation proportion (§IV-C1)
    tau: int = 2                        # staleness tolerance (§IV-C2)
    lr: float = 1e-4                    # paper Table IV
    batch_size: int = 100
    epochs: int = 1
    server_epochs: int = 1
    init_server_epochs: int = 5         # E_s warmup at r0 (Algorithm 1 l.5-6)
    threshold: float = 0.95             # pseudo-label confidence
    staleness_function: str = "exponential"
    round_weight_function: str = "exponential"
    adaptive_lr: bool = True
    supervised_weight_mode: str = "adaptive"   # adaptive|fixed_alpha|fixed_beta
    num_groups: int = 3
    group_based: bool = True
    sparse_comm: bool = True
    sparse_threshold: object = "p0.2"    # top-20% magnitude (ACO ~ 0.49)
    error_feedback: bool = False         # beyond-paper: EF-sparsification
    l1: float = 1e-5                    # §IV-F L1 regularisation
    use_kernels: bool = False           # Pallas kernels (interpret on CPU)
    seed: int = 0
    latency_jitter: float = 0.05


@dataclass
class RoundLog:
    round: int
    time: float
    art: float
    participants: list
    stalenesses: dict
    forced: list
    metrics: dict = field(default_factory=dict)


class FedS3ATrainer:
    def __init__(self, data, config: FedS3AConfig | None = None):
        self.cfg = config or FedS3AConfig()
        self.data = data
        self.M = len(data["clients"])
        self.cnn = CNN_CONFIG
        self.rng = jax.random.PRNGKey(self.cfg.seed)

        self.client_epoch = make_client_epoch(
            self.cnn, batch_size=self.cfg.batch_size,
            threshold=self.cfg.threshold, l1=self.cfg.l1,
            use_kernel=self.cfg.use_kernels)
        self.server_epoch = make_server_epoch(
            self.cnn, batch_size=self.cfg.batch_size, l1=self.cfg.l1)
        self.predict = predict_fn(self.cnn)
        self.histogram = class_histogram(self.cnn)

        sizes = [len(c["x"]) for c in data["clients"]]
        # the paper's measured latency model operates on unscaled Table III
        # sizes; rescale so relative timing matches the paper regardless of
        # the synthetic scale factor
        ref_total = 453004  # Table III basic total
        f = ref_total / max(sum(sizes), 1)
        self.latencies = [paper_latency(int(s * f)) for s in sizes]
        self.scheduler = SemiAsyncScheduler(
            self.latencies, C=self.cfg.C, tau=self.cfg.tau,
            jitter=self.cfg.latency_jitter, seed=self.cfg.seed)

        self.comm = SparseComm(self.cfg.sparse_threshold,
                               use_kernel=self.cfg.use_kernels,
                               enabled=self.cfg.sparse_comm)

        self.g_fn = staleness_fn(self.cfg.staleness_function)
        self.participation = np.zeros((0, self.M))
        self.logs: list[RoundLog] = []

        self._init_models()

    def _init_models(self):
        cfg = self.cfg
        self.rng, k = jax.random.split(self.rng)
        params = init_cnn(self.cnn, k)
        opt = adam_init(params)
        # Algorithm 1: server warms up on labeled data before distributing
        for e in range(cfg.init_server_epochs):
            self.rng, k = jax.random.split(self.rng)
            params, opt, _ = self.server_epoch(
                params, opt, self.data["server"]["x"], self.data["server"]["y"],
                cfg.lr, k)
        self.global_params = params
        self.server_opt = opt
        # per-client state: (params, opt, base_version, base_global_params)
        self.clients = []
        for i in range(self.M):
            self.clients.append({
                "params": params,
                "opt": adam_init(params),
                "base_version": 0,
                "base_params": params,
            })
        self.global_version = 0

    # ------------------------------------------------------------------
    def _train_client(self, i, lr):
        st = self.clients[i]
        self.rng, k = jax.random.split(self.rng)
        x = self.data["clients"][i]["x"]
        params, opt = st["params"], st["opt"]
        for _ in range(self.cfg.epochs):
            params, opt, _ = self.client_epoch(params, opt, x, lr, k)
        st["params"], st["opt"] = params, opt
        return params

    def _distribute(self, i):
        """Send the current global model to client i (sparse diff)."""
        st = self.clients[i]
        delta, _ = self.comm.encode(self.global_params, st["base_params"])
        newp = self.comm.apply(st["base_params"], delta)
        st["params"] = newp
        st["base_params"] = newp
        st["base_version"] = self.global_version
        st["opt"] = adam_init(newp)

    def run_round(self):
        cfg = self.cfg
        prev_time = self.scheduler.state.time
        participants, stale, forced, t = self.scheduler.next_round()
        r = self.global_version

        # adaptive learning rates from round-weighted participation history
        lrs = adaptive_learning_rates(
            self.participation, base_lr=cfg.lr,
            round_weight=cfg.round_weight_function,
            adaptive=cfg.adaptive_lr)

        # participating clients train and upload sparse diffs
        client_models, sizes, stalenesses, hists = [], [], [], []
        for run in participants:
            i = run.client
            newp = self._train_client(i, float(lrs[i]))
            if cfg.error_feedback:
                res = self.clients[i].get("residual")
                if res is None:
                    res = jax.tree.map(jnp.zeros_like, newp)
                delta, _, res = self.comm.encode(
                    newp, self.clients[i]["base_params"], residual=res)
                self.clients[i]["residual"] = res
            else:
                delta, _ = self.comm.encode(newp, self.clients[i]["base_params"])
            uploaded = self.comm.apply(self.clients[i]["base_params"], delta)
            client_models.append(uploaded)
            sizes.append(len(self.data["clients"][i]["x"]))
            stalenesses.append(stale[i])
            hists.append(np.asarray(
                self.histogram(uploaded, jnp.asarray(self.data["clients"][i]["x"]))))

        # server supervised epoch on the current global model (Eq. 6)
        self.rng, k = jax.random.split(self.rng)
        sp, self.server_opt, _ = self.server_epoch(
            self.global_params, self.server_opt,
            self.data["server"]["x"], self.data["server"]["y"], cfg.lr, k)

        groups = None
        if cfg.group_based and len(client_models) > 1:
            groups = group_clients(np.stack(hists),
                                   min(cfg.num_groups, len(client_models)),
                                   seed=cfg.seed)

        fw = supervised_weight(r, C=cfg.C, M=self.M,
                               mode=cfg.supervised_weight_mode)
        self.global_params = agg.aggregate(
            sp, client_models, data_sizes=sizes, stalenesses=stalenesses,
            g_fn=self.g_fn, f_weight=fw, groups=groups,
            use_kernel=cfg.use_kernels)
        self.global_version += 1

        # distribution: latest + deprecated clients get the new model
        part_ids = [run.client for run in participants]
        for i in set(part_ids) | set(forced):
            self._distribute(i)

        row = np.zeros((1, self.M))
        row[0, part_ids] = 1
        self.participation = np.concatenate([self.participation, row])

        log = RoundLog(round=r, time=t, art=t - prev_time,
                       participants=part_ids,
                       stalenesses={i: stale[i] for i in part_ids},
                       forced=forced)
        self.logs.append(log)
        return log

    # ------------------------------------------------------------------
    def evaluate(self, params=None):
        params = params if params is not None else self.global_params
        test = self.data["test"]
        preds = np.asarray(self.predict(params, jnp.asarray(test["x"])))
        return weighted_metrics(test["y"], preds, self.cnn.num_classes)

    def train(self, rounds=None, *, eval_every=0):
        rounds = rounds or self.cfg.rounds
        for _ in range(rounds):
            log = self.run_round()
            if eval_every and (log.round + 1) % eval_every == 0:
                log.metrics = self.evaluate()
        final = self.evaluate()
        art = float(np.mean([l.art for l in self.logs]))
        return {"metrics": final, "art": art, "aco": self.comm.aco,
                "rounds": len(self.logs)}
