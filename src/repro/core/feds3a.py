"""The FedS3A trainer: ties together the semi-async scheduler, FSSL training,
group-based staleness-weighted aggregation, adaptive learning rates and
sparse-difference communication. Reproduces the paper's Tables V-XII.

Three round engines share the scheduler/aggregation math, selected by
``engine=`` (``"sequential" | "batched" | "sharded" | None``):

* ``"sequential"`` — the original one-client-at-a-time loop, kept as the
  reference implementation (the parity suite pins the others to it).
* ``"batched"`` — client state lives as a stacked flat (client, param)
  matrix; every participant's pseudo-label epoch runs in ONE jitted call
  (client axis via vmap on accelerators, lax.map on CPU where XLA's batched
  GEMMs degrade), all upload deltas are thresholded/counted in one 2D-grid
  kernel launch with deferred on-device ACO accounting, and the stacked
  flat deltas feed the aggregation kernel directly. A handful of dispatches
  per round instead of dozens per client, zero per-message host syncs.
* ``"sharded"`` — the fleet engine: the batched engine's (K, N) client
  stacks are sharded row-wise across devices with ``shard_map`` over a
  ``clients`` mesh axis, so a multi-device host (or
  ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` on CPU) trains
  D client shards concurrently. Per-client base/residual state lives in
  (M, N) matrices gathered/scattered by participant index; aggregation is
  one psum over the client axis; grouping runs the on-device jitted
  k-means. The whole round is device-resident — zero host syncs (the
  deferred ACO read excepted). K that does not divide the device count is
  padded with zero-weight rows, sliced off before any accounting.
* ``None`` (default) — auto: sharded whenever more than one device is
  visible (and the model is small enough on CPU); batched on a single
  accelerator or for small CPU models (round overhead dominates there,
  measured ~3.5x per round); sequential for compute-bound single-device
  CPU training where the engines tie.

The legacy ``batched=True/False`` config flag maps onto
``engine="batched"/"sequential"`` when ``engine`` is unset.

Communication uses the compacted CSR wire format by default
(``wire_format="csr"``): uploads and distributions move real
(values, indices, row_ptr) payload arrays, the aggregation consumes them
via a fused scatter-add decode, and — under error feedback — per-client
residuals live in a capacity-bounded sparse store instead of dense (M, N)
state. ``wire_format="dense_masked"`` keeps the pre-compaction reference
behaviour (masked dense deltas, counted-not-materialized payloads).

Per-client base state is versioned by default (``base_store="versioned"``):
the server keeps a ring of the last ``tau + 2`` canonical reconstructions
plus one compacted chain delta per round transition
(``core.base_store.VersionedBaseStore``), a client's base is a ring lookup
by ``base_version``, and distribution is a chain-delta broadcast (each
transition payload on the wire once per round, ≤ tau + 1 of them, shared by
every listening client) instead of one encode per target. Server base
memory is O(tau * N + M)
rather than the O(M * N) the dense layouts needed. ``base_store="dense"``
keeps the legacy per-client stores (per-client trees / ``_base_rows`` /
``_base_mat``), whose per-client encode-against-own-base error the parity
suite pins against the sequential reference.
"""
from __future__ import annotations

import os
import queue
import threading
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.feds3a_cnn import CONFIG as CNN_CONFIG
from repro.core import aggregation as agg
from repro.core import fleet_ckpt
from repro.core.base_store import VersionedBaseStore
from repro.core.client_store import PagedClientStore
from repro.core.functions import (adaptive_learning_rates, staleness_fn,
                                  supervised_weight)
from repro.core.grouping import group_clients, init_index, kmeans_device
from repro.core.metrics import fleet_health, weighted_metrics
from repro.core.model_adapter import make_adapter
from repro.core.param_layout import ParamLayout
from repro.core.scheduler import SemiAsyncScheduler, paper_latency
from repro.core.sparse_comm import (CSR_FORMATS, MALFORM_KINDS, Q_BLOCK,
                                    SparseComm, WireIntegrityError,
                                    flatten_tree, unflatten_like)
from repro.distributed.sharding import (CLIENT_AXIS, CLIENT_PAYLOAD_SPECS,
                                        CLIENT_STACK_SPEC, CLIENT_VEC_SPEC,
                                        REPLICATED_SPEC, RING_SLOT_SPEC,
                                        RING_SPEC, client_mesh, padded_rows,
                                        payload_specs)
from repro.kernels.ops import csr_decode
from repro.optimizer import adam_init

ENGINES = ("sequential", "batched", "sharded")
BASE_STORES = ("versioned", "dense")
CLIENT_STORES = ("resident", "paged")

# auto engine selection: minimum participants per device before the sharded
# engine beats batched — below this the psum/collective overhead dominates
# the per-shard work (measured: K=8 on D=4 CPU devices, 2 rows/device, loses
# to the batched engine; 4+ rows/device wins)
MIN_SHARD_ROWS = 4

# client-axis partition specs for the sharded round stages (short aliases
# of the canonical specs in distributed.sharding)
_ROW = CLIENT_VEC_SPEC                  # (K,) per-client scalars
_ROW2 = CLIENT_STACK_SPEC               # (K, N) stacks / (K, 2) keys
_ROW3 = P(CLIENT_AXIS, None, None)      # (K, nb*B, F) padded data
_REP = REPLICATED_SPEC                  # replicated


@jax.jit
def _gather_rows(mat, idx):
    """(M, N) state matrix -> (Kp, N) stacked rows for this round."""
    return mat[idx]


_scatter_jit = None


def _scatter_rows(mat, idx, rows):
    """Write updated per-client rows back into the (M, N) state matrix.

    The caller always overwrites its reference with the result, so the
    input buffer is donated where the backend supports it (not XLA:CPU,
    which warns and ignores donation) — at fleet scale an undonated
    scatter copies the whole (M, N) matrix per round. Built lazily so
    importing this module never initializes the XLA client."""
    global _scatter_jit
    if _scatter_jit is None:
        _scatter_jit = jax.jit(
            lambda m, i, r: m.at[i].set(r),
            donate_argnums=(0,) if jax.default_backend() != "cpu" else ())
    return _scatter_jit(mat, idx, rows)


@dataclass
class FedS3AConfig:
    rounds: int = 20
    C: float = 0.6                      # participation proportion (§IV-C1)
    tau: int = 2                        # staleness tolerance (§IV-C2)
    lr: float = 1e-4                    # paper Table IV
    batch_size: int = 100
    epochs: int = 1
    server_epochs: int = 1
    init_server_epochs: int = 5         # E_s warmup at r0 (Algorithm 1 l.5-6)
    threshold: float = 0.95             # pseudo-label confidence
    staleness_function: str = "exponential"
    round_weight_function: str = "exponential"
    adaptive_lr: bool = True
    supervised_weight_mode: str = "adaptive"   # adaptive|fixed_alpha|fixed_beta
    num_groups: int = 3
    group_based: bool = True
    sparse_comm: bool = True
    sparse_threshold: object = "p0.2"    # top-20% magnitude (ACO ~ 0.49)
    wire_format: str = "csr"             # "csr": compacted payloads (values
                                         # + indices + row_ptr actually
                                         # materialized; bytes-on-wire is
                                         # the real payload size) |
                                         # "csr_q": quantized + packed CSR
                                         # (int8 values + per-row absmax
                                         # scale, int16 in-block index
                                         # offsets + block-count table;
                                         # ~3 bytes/element vs 8; rounding
                                         # error folds into the EF residual)
                                         # | "dense_masked": legacy reference
                                         # (masked dense deltas, counted nnz)
    q_dtype: str = "int8"                # csr_q value dtype: "int8" (per-row
                                         # absmax scale) | "fp16" (wide
                                         # dynamic-range fallback, no scale)
    wire_capacity: object = None         # per-row payload capacity override
                                         # (None: auto from the keep frac)
    residual_frac: float = 0.25          # EF residual store: top fraction of
                                         # N kept by magnitude (1.0 =
                                         # lossless); the sharded store is
                                         # O(M * residual_frac * N)
    base_store: str = "versioned"        # "versioned": ring of tau+2 global
                                         # reconstructions + chain deltas,
                                         # chain-delta broadcast
                                         # distribution, O(tau*N + M) server
                                         # memory | "dense": legacy
                                         # per-client base state (O(M*N)),
                                         # per-target distribution encodes
    client_store: str = "resident"       # "resident": per-client EF residual
                                         # rows (and the batched engines'
                                         # padded data stack) live as (M,...)
                                         # device arrays — the parity-pinned
                                         # reference | "paged": host-resident
                                         # pages (core.client_store) with a
                                         # device gather/scatter window over
                                         # the round's participants only —
                                         # device client-state bytes are
                                         # O(K * page), flat in M. Requires
                                         # base_store="versioned"
    paged_dir: object = None             # client_store="paged": directory
                                         # for memory-mapped page files
                                         # (None = anonymous host RAM, which
                                         # Linux commits lazily)
    error_feedback: bool = False         # beyond-paper: EF-sparsification
    l1: float = 1e-5                    # §IV-F L1 regularisation
    use_kernels: bool = False           # Pallas kernels (interpret on CPU)
    engine: object = None               # "sequential" | "batched" | "sharded"
                                        # | None = auto (sharded on multi-
                                        # device hosts, batched on a single
                                        # accelerator / small CPU model,
                                        # sequential for compute-bound
                                        # single-device CPU training)
    batched: object = None              # legacy alias: True/False map to
                                        # engine="batched"/"sequential" when
                                        # ``engine`` is unset
    cnn: object = None                  # CNNConfig override (None: paper §V-B)
    model: object = None                # model-zoo ModelConfig (configs.base)
                                        # federated as a final-token
                                        # classifier via core.model_adapter;
                                        # None = the paper CNN (``cnn``)
    chunk_size: int = 0                 # > 0: partition the flat parameter
                                        # axis into leaf-aligned chunks
                                        # (core.param_layout) and stream the
                                        # round's delta pipeline chunk by
                                        # chunk — peak device delta memory is
                                        # O(K * chunk) instead of O(K * N).
                                        # 0 = the flat single-chunk path
    param_layout: object = None         # explicit ParamLayout (wins over
                                        # chunk_size); a single-chunk layout
                                        # with no overrides routes through
                                        # the flat path bit-identically
    layer_keep_frac: object = None      # per-layer sparsity: {leaf-name
                                        # substring: keep_frac | (keep_frac,
                                        # residual_frac) | {"keep_frac": ...,
                                        # "residual_frac": ...}}. Requires
                                        # chunking (a chunk never spans two
                                        # leaves with different overrides)
    seed: int = 0
    latency_jitter: float = 0.05
    traffic: object = None              # fault profile (core.traffic.
                                        # TrafficModel): crash-mid-run,
                                        # upload loss, heavy-tailed latency,
                                        # leave/rejoin churn, late joins.
                                        # None = the happy path (exactly the
                                        # pre-fault behaviour, draw for
                                        # draw). Requires the versioned base
                                        # store (rejoin resync is a ring
                                        # concept)
    round_deadline: object = None       # seconds of simulated time per
                                        # round: when k uploads can't arrive
                                        # in time the server aggregates a
                                        # degraded quorum (>= quorum_floor)
                                        # instead of waiting. None = wait
                                        # for k forever
    quorum_floor: int = 1               # minimum uploads a degraded round
                                        # may aggregate; below it the
                                        # scheduler raises FleetStalledError
    checkpoint_dir: object = None       # crash-consistent fleet checkpoints
                                        # (core.fleet_ckpt): atomic,
                                        # manifest-checksummed snapshots of
                                        # the COMPLETE round-boundary state;
                                        # ``restore()`` resumes bit-exactly.
                                        # Requires base_store="versioned"
    checkpoint_every: int = 0           # rounds between automatic train()
                                        # checkpoints (0 = only explicit
                                        # ``save_checkpoint()`` calls)


@dataclass
class RoundLog:
    round: int
    time: float
    art: float
    participants: list
    stalenesses: dict
    forced: list
    metrics: dict = field(default_factory=dict)
    # fault-layer fields (defaults = the happy path, so fault-free logs are
    # unchanged semantically)
    degraded: bool = False       # aggregated fewer than target_k uploads
    deadline_hit: bool = False   # the round deadline forced the aggregation
    quorum: int = 0              # uploads actually aggregated
    target_k: int = 0            # the participation threshold k
    crashes: int = 0             # crash-mid-run events during the round
    lost: list = field(default_factory=list)      # uploads lost in transit
    departed: list = field(default_factory=list)  # clients that churned out
    rejoined: list = field(default_factory=list)  # clients back online
    resynced: list = field(default_factory=list)  # rejoiners needing the
                                                  # full-model resync (ring
                                                  # version evicted)
    corrupted: list = field(default_factory=list)  # uploads quarantined by
                                                   # the wire-integrity
                                                   # gauntlet (never decoded,
                                                   # never booked)


class FedS3ATrainer:
    def __init__(self, data, config: FedS3AConfig | None = None):
        self.cfg = config or FedS3AConfig()
        self.data = data
        self.M = len(data["clients"])
        self.cnn = self.cfg.cnn if self.cfg.cnn is not None else CNN_CONFIG
        # one adapter owns every model closure (epochs, histograms, predict)
        # — the paper CNN delegates to the exact pseudo_label factories the
        # trainer used to bind directly, a model-zoo ModelConfig routes to
        # the LM-as-classifier adapter
        model = self.cfg.model if self.cfg.model is not None else self.cnn
        self.adapter = make_adapter(
            model, batch_size=self.cfg.batch_size,
            threshold=self.cfg.threshold, l1=self.cfg.l1,
            use_kernel=self.cfg.use_kernels, epochs=self.cfg.epochs)
        self.layout = self._resolve_layout()
        self.chunked = self.layout is not None
        self.engine = self._select_engine()
        if self.cfg.base_store not in BASE_STORES:
            raise ValueError(f"base_store must be one of {BASE_STORES}, "
                             f"got {self.cfg.base_store!r}")
        self.base_store = self.cfg.base_store
        if self.cfg.client_store not in CLIENT_STORES:
            raise ValueError(f"client_store must be one of {CLIENT_STORES}, "
                             f"got {self.cfg.client_store!r}")
        self.paged = self.cfg.client_store == "paged"
        if self.paged and self.base_store != "versioned":
            raise ValueError(
                "client_store='paged' requires base_store='versioned': the "
                "paged layout keeps no per-client base state at all — a "
                "client's base is its ring version, already host-side")
        # legacy attribute: any stacked-flat-state engine counts as batched;
        # the chunked round body is stacked on every engine (the sequential
        # engine's chunked rounds share it — same RNG stream, same math)
        self.batched = self.engine != "sequential" or self.chunked
        self.mesh = client_mesh() if self.engine == "sharded" else None
        self.rng = jax.random.PRNGKey(self.cfg.seed)

        self._stage1_jits = {}      # sharded train+upload(+hist) stages
        self._stage2_jits = {}      # sharded aggregate+distribute stages
        self._groupw_jits = {}      # sharded on-device kmeans+weights

        self.client_epoch = self.adapter.client_epoch
        self.server_epoch = self.adapter.server_epoch
        self.predict = self.adapter.predict
        self.histogram = self.adapter.histogram
        if self.batched:
            self.batched_epoch = self.adapter.batched_epoch
            self.histogram_batch = self.adapter.histogram_batch
            self.server_epoch_flat = self.adapter.server_epoch_flat
            self._build_padded_data()

        sizes = [len(c["x"]) for c in data["clients"]]
        # the paper's measured latency model operates on unscaled Table III
        # sizes; rescale so relative timing matches the paper regardless of
        # the synthetic scale factor
        ref_total = 453004  # Table III basic total
        f = ref_total / max(sum(sizes), 1)
        self.latencies = [paper_latency(int(s * f)) for s in sizes]
        if self.cfg.traffic is not None and self.base_store != "versioned":
            raise ValueError(
                "fault injection (traffic=) requires base_store='versioned':"
                " rejoin re-basing (chain suffix vs full-model resync) is "
                "defined against the reconstruction ring")
        if self.cfg.checkpoint_dir is not None \
                and self.base_store != "versioned":
            raise ValueError(
                "checkpoint_dir requires base_store='versioned': the "
                "checkpoint snapshots the reconstruction ring + chain; the "
                "legacy dense per-client base state has no serialized form")
        self.scheduler = SemiAsyncScheduler(
            self.latencies, C=self.cfg.C, tau=self.cfg.tau,
            jitter=self.cfg.latency_jitter, seed=self.cfg.seed,
            traffic=self.cfg.traffic, deadline=self.cfg.round_deadline,
            quorum_floor=self.cfg.quorum_floor)

        self.comm = SparseComm(self.cfg.sparse_threshold,
                               use_kernel=self.cfg.use_kernels,
                               enabled=self.cfg.sparse_comm,
                               wire_format=self.cfg.wire_format,
                               capacity=self.cfg.wire_capacity,
                               residual_frac=self.cfg.residual_frac,
                               q_dtype=self.cfg.q_dtype,
                               layout=self.layout)
        # the engines branch on the *effective* wire format: disabled
        # sparsification always moves dense payloads. Both CSR formats
        # share the engine plumbing (payload tuples thread through the
        # stages opaquely); ``_csr_wire`` gates the shared paths and
        # ``wire_fmt`` picks the format-specific blend/specs.
        self.wire_fmt = self.comm.wire_format \
            if (self.comm.enabled and self.comm.wire_format in CSR_FORMATS) \
            else "dense"
        self._csr_wire = self.wire_fmt != "dense"
        # payload tuple arity (excl. stored): (vals, idx) vs the quantized
        # (qvals, qoffs, qcnt, scales) quadruple
        self._payload_arity = {"csr": 2, "csr_q": 4}.get(self.wire_fmt, 0)
        if self.chunked:
            if not self._csr_wire:
                raise ValueError(
                    "chunked layouts require a CSR-family wire format with "
                    "sparse_comm enabled: the chunked round streams "
                    "compacted per-chunk payloads")
            if self.base_store != "versioned":
                raise ValueError(
                    "chunked layouts require base_store='versioned': chunk "
                    "bases are gathered from the reconstruction ring one "
                    "chunk at a time")

        self.g_fn = staleness_fn(self.cfg.staleness_function)
        self.participation = np.zeros((0, self.M))
        self._data_window_bytes = 0
        self.logs: list[RoundLog] = []
        # checkpoint machinery: per-log packed-bytes cache (logs are
        # append-only, so each is encoded once per run) and the lazily
        # started persistent writer thread (at most one write in flight)
        self._log_pack: list[bytes] = []
        self._ckpt_thread = None
        self._ckpt_queue = None
        self._ckpt_exc = None

        self._init_models()

    def _resolve_layout(self):
        """Resolve chunk_size / param_layout / layer_keep_frac to the
        trainer's effective :class:`ParamLayout` — or ``None`` for the flat
        path. A resolved layout that ``is_flat`` (one chunk, no overrides)
        also maps to ``None``: the degenerate single-chunk layout IS the
        historical flat path, routed through exactly the same code."""
        cfg = self.cfg
        layout = cfg.param_layout
        if layout is None:
            if cfg.layer_keep_frac and not cfg.chunk_size:
                raise ValueError(
                    "layer_keep_frac requires chunk_size > 0 or an explicit "
                    "param_layout: per-layer sparsity is a property of the "
                    "leaf-aligned chunks")
            if not cfg.chunk_size:
                return None
            layout = ParamLayout.from_template(
                self.adapter.template, cfg.chunk_size,
                overrides=cfg.layer_keep_frac)
        return None if layout.is_flat else layout

    def _select_engine(self):
        """Resolve cfg.engine / legacy cfg.batched to a concrete engine.

        Auto (engine=None, batched=None): the stacked-flat engines win
        wherever round overhead (dispatch, per-message passes, host syncs)
        dominates — always on accelerators, and on CPU for small models;
        compute-bound single-device CPU training keeps the sequential
        reference. With more than one visible device the sharded fleet
        engine takes over from batched — but only when the expected round
        carries at least ``MIN_SHARD_ROWS`` participants per device: tiny
        rounds lose more to the psum/collective overhead than they gain
        from the extra devices (measured at K=8, D=4 on CPU).
        """
        cfg = self.cfg
        engine = cfg.engine
        if cfg.batched is not None:
            warnings.warn(
                "FedS3AConfig(batched=...) is deprecated since the engine "
                "selector landed; use engine='batched' / engine="
                "'sequential' instead", DeprecationWarning, stacklevel=3)
        if engine is None and cfg.batched is not None:
            engine = "batched" if cfg.batched else "sequential"
        if engine is None:
            stacked = (jax.default_backend() != "cpu"
                       or self.adapter.param_count() <= 300_000)
            if not stacked:
                engine = "sequential"
            else:
                D = len(jax.devices())
                # the scheduler admits ceil(C * M) uploads per round
                k = max(int(np.ceil(cfg.C * self.M)), 1)
                engine = "sharded" if (D > 1 and k >= MIN_SHARD_ROWS * D) \
                    else "batched"
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES} or None, "
                             f"got {engine!r}")
        return engine

    def _build_padded_data(self):
        """Pad every client's data to a common batch count once, so the
        batched epoch indexes a fixed (M, nb*B, F) device stack per round.

        Paged client store: the padded stack stays HOST-side and only the
        round's participant rows are placed on device (``_gather_data``).
        Pooled fleet datasets (``data["pool"]`` — M clients aliasing P
        distinct shards) store only the P distinct rows, with ``_data_map``
        sending client i to its shard row; at M=1,000,000 the device (and
        host) data footprint is what a 64-client run pays."""
        B = self.cfg.batch_size
        pool = self.data.get("pool") if self.paged else None
        rows = min(int(pool), self.M) if pool else self.M
        clients = self.data["clients"][:rows]
        F = clients[0]["x"].shape[1]
        nb = max(max((len(c["x"]) + B - 1) // B, 1) for c in clients)
        xs = np.zeros((rows, nb * B, F), np.float32)
        valid = np.zeros((rows, nb * B), np.float32)
        for i, c in enumerate(clients):
            n = len(c["x"])
            xs[i, :n] = c["x"]
            valid[i, :n] = 1.0
        if self.paged:
            self._x_pad_h = xs
            self._valid_pad_h = valid
            self._data_map = np.arange(self.M, dtype=np.int64) % rows
            self._data_row_bytes = int(xs[0].nbytes + valid[0].nbytes)
        else:
            self._x_pad = jnp.asarray(xs)
            self._valid_pad = jnp.asarray(valid)

    def _gather_data(self, ids):
        """Participants' padded data rows as device arrays. Resident: a
        device-side fancy index of the (M, nb*B, F) stack. Paged: a host
        fancy index + device put of just the window — same values bit for
        bit (pure data movement, no arithmetic)."""
        if self.paged:
            rows = self._data_map[np.asarray(ids, np.int64)]
            xs = jnp.asarray(self._x_pad_h[rows])
            vs = jnp.asarray(self._valid_pad_h[rows])
            self._data_window_bytes = int(xs.nbytes + vs.nbytes)
            return xs, vs
        idx = jnp.asarray(ids)
        return self._x_pad[idx], self._valid_pad[idx]

    def _init_models(self):
        cfg = self.cfg
        self.rng, k = jax.random.split(self.rng)
        params = self.adapter.init(k)
        opt = adam_init(params)
        # Algorithm 1: server warms up on labeled data before distributing
        for e in range(cfg.init_server_epochs):
            self.rng, k = jax.random.split(self.rng)
            params, opt, _ = self.server_epoch(
                params, opt, self.data["server"]["x"], self.data["server"]["y"],
                cfg.lr, k)
        self._template = params       # leaf shapes/dtypes for unflatten
        self.global_params = params
        self.server_opt = opt
        self._global_flat = flatten_tree(params)
        # one zeroed Adam state shared by every distribution (JAX arrays are
        # immutable, so the template is safe to alias across clients)
        self._zero_opt = adam_init(params)
        n = self._global_flat.shape[0]
        if self.base_store == "versioned":
            # staleness-windowed versioned base store, shared by all three
            # engines: ring of tau+2 canonical reconstructions + one chain
            # delta per retained transition + per-client versions. No
            # per-client base state exists anywhere — a client's base is
            # the ring row its base_version indexes.
            self.store = VersionedBaseStore(self._global_flat, self.M,
                                            cfg.tau)
            # late-join clients start offline: parked at version 0 and
            # detached, so their stale version never wedges ring eviction;
            # they re-attach through the rejoin path (chain suffix or full
            # resync) at their first online boundary
            if self.scheduler.initial_offline:
                self.store.detach(self.scheduler.initial_offline)
            self._advance_jit = None
        if self.batched:
            # server Adam state carries over from the warmup, flattened once
            self.server_opt = {"m": flatten_tree(opt["m"]),
                               "v": flatten_tree(opt["v"]), "t": opt["t"]}
            self._key_jits = {}
            self._upload_jits = {}
            self._finalize_jit = None
            if self.base_store == "dense":
                self._base_version = np.zeros(self.M, dtype=int)
                if self.engine == "sharded":
                    # legacy fleet layout: ONE (M, N) base matrix so each
                    # round is a single gather of participant rows and a
                    # single scatter back — no per-row python traffic at
                    # thousand-client scale (but O(M * N) server memory;
                    # the versioned store removes it)
                    self._base_mat = jnp.broadcast_to(
                        self._global_flat, (self.M, n))
                else:
                    # per-client base params as flat (N,) device rows
                    # (initially all aliasing the warmed-up global model —
                    # JAX arrays are immutable); clients always start a
                    # round at their base model, so no per-client trees are
                    # kept at all. Rows rather than one (M, N) array so
                    # distribution replaces references instead of copying
                    # the whole fleet's parameters every round.
                    self._base_rows = [self._global_flat] * self.M
            if cfg.error_feedback and not self.paged:
                if self.chunked:
                    # chunked EF pages: every engine stores per-client
                    # residuals as (M, rcap_total) CSR segments — the
                    # concatenation of the per-chunk capacities, holding
                    # GLOBAL column indices (chunk_encode_body re-localizes
                    # per chunk)
                    rcap = self.comm.residual_capacity_total()
                    self._res_vals = jnp.zeros((self.M, rcap), jnp.float32)
                    self._res_idx = jnp.zeros((self.M, rcap), jnp.int32)
                elif self.engine == "sharded":
                    if self._csr_wire:
                        # sparse residual store: per-client residuals live in
                        # capacity-bounded CSR rows — O(M * rcap) instead of
                        # the dense (M, N) matrix that blocked >100k-client
                        # fleets (rcap*(4+4) bytes/client vs 4N dense). No
                        # per-row count is kept: padding slots hold value 0
                        # at index 0, so the decode needs none.
                        rcap = self.comm.residual_capacity(n)
                        self._res_vals = jnp.zeros((self.M, rcap),
                                                   jnp.float32)
                        self._res_idx = jnp.zeros((self.M, rcap), jnp.int32)
                    else:
                        self._residual_mat = jnp.zeros((self.M, n),
                                                       jnp.float32)
                else:
                    zero = jnp.zeros_like(self._global_flat)
                    self._residual_rows = [zero] * self.M
        elif self.base_store == "dense":
            # per-client state: (params, opt, base_version, base_params)
            self.clients = []
            for i in range(self.M):
                self.clients.append({
                    "params": params,
                    "opt": self._zero_opt,
                    "base_version": 0,
                    "base_params": params,
                })
        else:
            # versioned sequential: a client's params/opt/base are all
            # derived from its ring version; only the EF residual tree is
            # genuinely per-client state
            self.clients = [{} for _ in range(self.M)]
        if self.paged:
            # host-resident per-client pages + a device participant window;
            # the residual page layout follows the effective wire format
            # (CSR rows for the CSR family, dense rows for dense_masked,
            # none with EF off — the store still carries the counters)
            layout = ("csr" if self._csr_wire else "dense") \
                if cfg.error_feedback else "none"
            rcap = self.comm.residual_capacity_total() if self.chunked \
                else self.comm.residual_capacity(n)
            self.cstore = PagedClientStore(
                self.M, n, rcap, layout=layout,
                paged_dir=cfg.paged_dir)
            self.cstore.adopt_versions(self.store.client_version,
                                       self.store.detached)
        self.global_version = 0

    # ------------------------------------------------------------------
    @property
    def global_params(self):
        """Global model as a pytree. The batched engine keeps the canonical
        state flat and materializes the tree lazily (evaluate / sequential
        interop); the sequential engine assigns the tree directly."""
        if self._gp_tree is None:
            self._gp_tree = unflatten_like(self._global_flat, self._template)
        return self._gp_tree

    @global_params.setter
    def global_params(self, tree):
        self._gp_tree = tree

    @property
    def base_versions(self):
        """(M,) per-client base model versions — engine/store-agnostic."""
        if self.base_store == "versioned":
            return self.store.client_version.copy()
        if self.engine == "sequential":
            return np.array([c["base_version"] for c in self.clients])
        return np.asarray(self._base_version).copy()

    # ------------------------------------------------------------------
    def _train_client(self, i, lr):
        """Run client i's local epochs; returns (trained, base) trees."""
        self.rng, k = jax.random.split(self.rng)
        x = self.data["clients"][i]["x"]
        if self.base_store == "versioned":
            # the base is a ring lookup by the client's version — identical
            # for every client at that version, no per-client state read
            base = unflatten_like(self.store.gather([i])[0], self._template)
            params, opt = base, self._zero_opt
        else:
            st = self.clients[i]
            base = st["base_params"]
            params, opt = st["params"], st["opt"]
        for e in range(self.cfg.epochs):
            # epoch e > 0 folds its index into the per-round client key so
            # each epoch draws fresh dropout masks (the batched engine does
            # the identical fold; epoch 0 keeps the raw key so E=1 runs are
            # unchanged). The former reuse of one key replayed the same
            # masks every epoch.
            ke = k if e == 0 else jax.random.fold_in(k, e)
            params, opt, _ = self.client_epoch(params, opt, x, lr, ke)
        if self.base_store == "dense":
            st["params"], st["opt"] = params, opt
        return params, base

    def _distribute(self, i):
        """Send the current global model to client i (sparse diff against
        its dense per-client base; the versioned store broadcasts chain
        payloads instead — see ``_advance_versioned``)."""
        st = self.clients[i]
        if st["base_version"] == self.global_version:
            # no-op diff: nothing to transmit. The client was already
            # distributed at this exact version, so its params equal
            # base_params and its opt is already the zeroed template.
            return
        delta, _ = self.comm.encode(self.global_params, st["base_params"])
        # disabled sparsification moves the dense model: the copy is exact
        # (base + (g - base) re-rounds; g itself does not)
        newp = self.comm.apply(st["base_params"], delta) \
            if self.comm.enabled else self.global_params
        st["params"] = newp
        st["base_params"] = newp
        st["base_version"] = self.global_version
        st["opt"] = self._zero_opt

    # ------------------------------------------------------------------
    # versioned base store plumbing (all engines)
    def _advance_encode_body(self):
        """Traced body shared by every engine's finalize stage: ONE chain-
        transition encode of the new global model against the previous
        canonical reconstruction R_r. Returns (R_{r+1}, payload) where the
        payload tuple is the wire tuple + stored count under the CSR family
        — (values, indices, stored) for f32 csr, (qvals, qoffs, qcnt,
        scales, stored) for csr_q, where the reconstruction folds in the
        DEQUANTIZED decode so the ring stays the canonical f32 model every
        receiver of the quantized chain rebuilds — (nnz,) under
        dense_masked, and () with sparsification disabled — there R_{r+1}
        is the new global model bit-for-bit, which is what makes the
        versioned store reproduce the dense store exactly."""
        if self._csr_wire:
            core = self.comm.csr_core(False)

            def body(new_flat, prev):
                payload, stored, decoded = core(new_flat[None], prev[None])
                return prev + decoded[0], \
                    tuple(p[0] for p in payload) + (stored[0],)

            return body
        core = self.comm.batch_core(False) if self.comm.enabled else None

        def body(new_flat, prev):
            if core is None:
                return new_flat, ()
            masked, nnz = core(new_flat[None], prev[None])
            return prev + masked[0], (nnz[0],)

        return body

    def _chain_entry(self, payload):
        """Payload tuple from ``_advance_encode_body`` -> the store's chain
        record ({"stored": count[, "vals", "idx"]}; csr_q keeps the chain
        in its quantized wire form — what actually broadcasts — so server
        chain memory shrinks with the payloads)."""
        if self.wire_fmt == "csr":
            return {"vals": payload[0], "idx": payload[1],
                    "stored": payload[2]}
        if self.wire_fmt == "csr_q":
            return {"qvals": payload[0], "qoffs": payload[1],
                    "qcnt": payload[2], "scale": payload[3],
                    "stored": payload[4]}
        if self.comm.enabled:
            return {"stored": payload[0]}
        return {"stored": self._global_flat.shape[0]}

    def _distribution_plan(self, part_ids, ev):
        """Who restarts from the new global model at this boundary, and how.

        Returns ``(targets, resync)``: ``targets`` receive the chain-delta
        broadcast (or a per-target encode under the dense store) — online
        participants, tau-forced clients, lost-upload clients (their run
        finished but the payload evaporated, so they rebase like any other
        listener) and in-window rejoiners; ``resync`` are rejoiners whose
        parked version was evicted from the ring while they were away and
        need the explicit full-model payload instead. Participants that
        churned out after uploading stay aggregated but get nothing — there
        is nobody to send to. Fault-free this reduces exactly to the old
        ``participants | forced`` set. ``ev.resynced`` is filled as a side
        effect so the round log records the resync path firing.
        """
        online = self.scheduler.state.online
        chain, resync = [], []
        if ev.rejoined:
            chain, resync = self.store.split_rejoined(
                ev.rejoined, self.global_version)
        targets = sorted(set(i for i in part_ids if online[i])
                         | set(ev.forced) | set(ev.lost)
                         | set(ev.corrupted) | set(chain))
        ev.resynced = resync
        return targets, resync

    def _retired_ids(self, ev):
        """Clients whose server-side EF residual must be retired at this
        boundary: tau-forced restarts (the pre-fault behaviour), lost
        uploads and rejoiners (they restart from the new global model —
        fresh base, fresh residual) and departures (their trajectory is
        gone; keeping mass accumulated against an abandoned base would be
        re-offered as drift on rejoin). Retiring happens in the
        distribution phase — AFTER the upload encode — because a departed
        participant's encode this round legitimately consumed its
        then-current residual. Quarantined (corrupt) uploads retire
        exactly like lost ones: the payload was produced (consuming the
        residual) but never aggregated."""
        return sorted(set(ev.forced) | set(ev.lost) | set(ev.corrupted)
                      | set(ev.departed) | set(ev.rejoined))

    def _advance_versioned(self, recon, payload, ev, part_ids):
        """Install the new reconstruction + chain delta, detach departures,
        book the chain-delta broadcast (and any full-model resyncs), bump
        the targets, retire dead residuals."""
        targets, resync = self._distribution_plan(part_ids, ev)
        if ev.departed:
            # departures park (version kept for a possible in-window
            # rejoin) but stop constraining ring eviction — detach BEFORE
            # advance so an offline straggler can't wedge the window
            self.store.detach(ev.departed)
        self.store.advance(recon, self._chain_entry(payload),
                           self.global_version)
        self.store.account_distribution(self.comm, targets)
        if resync:
            self.store.resync(self.comm, resync)
        self._reset_forced_residuals(self._retired_ids(ev))

    def _reset_forced_residuals(self, forced):
        """A deprecated client's forced restart discards its in-flight
        trajectory AND its error-feedback residual — the residual was
        accumulated against a base the client no longer holds (see the
        SparseComm docstring; pinned in tests/test_error_feedback.py).
        Under faults the same retirement applies to lost-upload clients,
        departures and rejoiners (see ``_retired_ids``)."""
        if not self.cfg.error_feedback or not forced:
            return
        ids = sorted(set(forced))
        if self.paged:
            # page invalidation, queued AFTER this round's residual
            # writeback so the scatter-then-retire order matches the
            # resident engines' sequence
            self.cstore.retire(ids)
            return
        if self.chunked or self.engine == "sharded":
            fidx = jnp.asarray(ids)
            if self._csr_wire:
                shape = (len(ids), self._res_vals.shape[1])
                self._res_vals = _scatter_rows(
                    self._res_vals, fidx, jnp.zeros(shape, jnp.float32))
                self._res_idx = _scatter_rows(
                    self._res_idx, fidx, jnp.zeros(shape, jnp.int32))
            else:
                self._residual_mat = _scatter_rows(
                    self._residual_mat, fidx,
                    jnp.zeros((len(ids), self._residual_mat.shape[1]),
                              jnp.float32))
        elif self.engine == "batched":
            zero = jnp.zeros_like(self._global_flat)
            for i in ids:
                self._residual_rows[i] = zero
        else:
            for i in ids:
                self.clients[i].pop("residual", None)

    def _quarantine_uploads(self, ev):
        """Run every corrupt-fated upload through the wire-integrity
        gauntlet at the trust boundary. The scheduler decided WHICH runs
        the traffic model damaged (``ev.corrupted``); here the damage is
        materialized deterministically — a nominal payload malformed by
        one class from :data:`MALFORM_KINDS`, picked by a client/round
        hash so the trace is engine-independent and replays bit-exactly —
        and :meth:`SparseComm.validate_payload` must reject it. Rejection
        IS the quarantine: the payload is never decoded, never aggregated
        and never booked (the same no-delivery path lost uploads take; EF
        retirement happens in ``_retired_ids``). A malformed payload that
        somehow passed validation would silently poison the aggregate, so
        that raises outright. Host-only and outside every jitted round
        body — rounds without corruption pay nothing."""
        if not ev.corrupted or not self._csr_wire:
            # dense-family messages carry no payload arrays to damage;
            # the scheduler's no-delivery quarantine already applied
            return
        n = int(self._global_flat.shape[0])
        cap = 4                       # any capacity: validation infers it
        stored = np.full(1, cap, np.int64)
        if self.wire_fmt == "csr_q":
            vdt = np.int8 if self.comm.q_dtype == "int8" else np.float16
            blocks = np.zeros((1, (n + Q_BLOCK - 1) // Q_BLOCK), np.int64)
            blocks[0, 0] = cap
            nominal = {"nnz": stored, "total": n, "rows": 1,
                       "values": np.zeros((1, cap), vdt),
                       "indices": np.zeros((1, cap), np.int16),
                       "blocks": blocks,
                       "scales": np.ones(1, np.float32)}
        else:
            nominal = {"nnz": stored, "total": n, "rows": 1,
                       "values": np.zeros((1, cap), np.float32),
                       "indices": np.zeros((1, cap), np.int32)}
        for c in ev.corrupted:
            kind = MALFORM_KINDS[
                (c * 2654435761 + self.global_version) % len(MALFORM_KINDS)]
            bad = self.comm.malform_stats(nominal, kind)
            try:
                self.comm.validate_payload(bad)
            except WireIntegrityError:
                continue              # quarantined
            raise RuntimeError(
                f"malformed upload (client {c}, kind {kind!r}) passed "
                f"wire-integrity validation — quarantine is broken")

    # ------------------------------------------------------------------
    def run_round(self):
        if self.chunked:
            return self._run_round_chunked()
        if self.engine == "sharded":
            return self._run_round_sharded()
        if self.engine == "batched":
            return self._run_round_batched()
        return self._run_round_sequential()

    def _round_prologue(self):
        """Advance the scheduler one boundary. Returns ``(prev_time, ev,
        lrs)`` with ``ev`` the scheduler's RoundResult — participants /
        staleness / forced restarts plus the fault-layer consequences
        (lost uploads, churn, degradation) every engine threads through
        the same distribution plan."""
        prev_time = self.scheduler.state.time
        if self.paged:
            # swap point of the page double-buffer: the previous round's
            # queued residual writebacks / retirements have overlapped the
            # inter-round host work; drain them before this round gathers
            self.cstore.flush()
        ev = self.scheduler.next_round()
        self._quarantine_uploads(ev)
        lrs = adaptive_learning_rates(
            self.participation, base_lr=self.cfg.lr,
            round_weight=self.cfg.round_weight_function,
            adaptive=self.cfg.adaptive_lr)
        return prev_time, ev, lrs

    def _round_epilogue(self, prev_time, ev):
        part_ids = [run.client for run in ev.participants]
        row = np.zeros((1, self.M))
        row[0, part_ids] = 1
        self.participation = np.concatenate([self.participation, row])
        if self.paged:
            self.cstore.record_participation(part_ids,
                                             self.global_version - 1)
        log = RoundLog(round=self.global_version - 1, time=ev.time,
                       art=ev.time - prev_time, participants=part_ids,
                       stalenesses={i: ev.stale[i] for i in part_ids},
                       forced=ev.forced, degraded=ev.degraded,
                       deadline_hit=ev.deadline_hit, quorum=ev.quorum,
                       target_k=ev.target_k, crashes=ev.crashes,
                       lost=ev.lost, departed=ev.departed,
                       rejoined=ev.rejoined, resynced=ev.resynced,
                       corrupted=ev.corrupted)
        self.logs.append(log)
        return log

    def _server_step(self):
        """Server supervised epoch on the current global model (Eq. 6)."""
        self.rng, k = jax.random.split(self.rng)
        sp, self.server_opt, _ = self.server_epoch(
            self.global_params, self.server_opt,
            self.data["server"]["x"], self.data["server"]["y"],
            self.cfg.lr, k)
        return sp

    def _run_round_sequential(self):
        cfg = self.cfg
        prev_time, ev, lrs = self._round_prologue()
        participants, stale, forced, t = ev
        r = self.global_version

        # participating clients train and upload sparse diffs
        client_models, sizes, stalenesses, hists = [], [], [], []
        for run in participants:
            i = run.client
            newp, base = self._train_client(i, float(lrs[i]))
            if cfg.error_feedback and self.paged:
                if self._csr_wire:
                    # the residual is a CSR page: gather it, fold its
                    # decode into the encode, queue the new page back —
                    # identical math to the resident tree path (the page
                    # decodes to exactly the dense residual, and the
                    # delta+residual add is elementwise in flat space)
                    rv, rx = self.cstore.gather_csr([i])
                    delta, _, (nrv, nrx) = self.comm.encode_paged(
                        newp, base, rv[0], rx[0])
                    self.cstore.scatter_csr([i], nrv[None], nrx[None])
                else:
                    row = self.cstore.gather_dense([i])[0]
                    res = unflatten_like(row, newp)
                    delta, _, res = self.comm.encode(newp, base,
                                                     residual=res)
                    self.cstore.scatter_dense([i],
                                              flatten_tree(res)[None])
            elif cfg.error_feedback:
                res = self.clients[i].get("residual")
                if res is None:
                    res = jax.tree.map(jnp.zeros_like, newp)
                delta, _, res = self.comm.encode(newp, base, residual=res)
                self.clients[i]["residual"] = res
            else:
                delta, _ = self.comm.encode(newp, base)
            uploaded = self.comm.apply(base, delta)
            client_models.append(uploaded)
            sizes.append(len(self.data["clients"][i]["x"]))
            stalenesses.append(stale[i])
            hists.append(np.asarray(
                self.histogram(uploaded, jnp.asarray(self.data["clients"][i]["x"]))))

        sp = self._server_step()

        groups = None
        if cfg.group_based and len(client_models) > 1:
            groups = group_clients(np.stack(hists),
                                   min(cfg.num_groups, len(client_models)),
                                   seed=cfg.seed)

        fw = supervised_weight(r, C=cfg.C, M=self.M,
                               mode=cfg.supervised_weight_mode)
        self.global_params = agg.aggregate(
            sp, client_models, data_sizes=sizes, stalenesses=stalenesses,
            g_fn=self.g_fn, f_weight=fw, groups=groups,
            use_kernel=cfg.use_kernels)
        self.global_version += 1

        # distribution: latest + deprecated clients get the new model
        part_ids = [run.client for run in participants]
        if self.base_store == "versioned":
            # one chain-transition encode + chain-delta broadcast (each
            # transition payload once per round) instead of one encode per
            # target
            if self._advance_jit is None:
                self._advance_jit = jax.jit(self._advance_encode_body())
            new_flat = flatten_tree(self.global_params)
            recon, payload = self._advance_jit(new_flat, self.store.latest())
            self._advance_versioned(recon, payload, ev, part_ids)
        else:
            targets, _ = self._distribution_plan(part_ids, ev)
            for i in targets:
                self._distribute(i)
            self._reset_forced_residuals(forced)

        return self._round_epilogue(prev_time, ev)

    # ------------------------------------------------------------------
    # jitted round stages (built lazily; retrace per participant count)
    def _split_keys(self, K):
        """Chained per-participant RNG splits in one jitted scan — the same
        key sequence as the sequential path's repeated jax.random.split."""
        fn = self._key_jits.get(K)
        if fn is None:
            @jax.jit
            def fn(rng):
                def s(c, _):
                    c, k = jax.random.split(c)
                    return c, k
                return jax.lax.scan(s, rng, None, length=K)
            self._key_jits[K] = fn
        self.rng, keys = fn(self.rng)
        return keys

    def _encode_upload_body(self, with_residual, with_hist):
        """Traced body shared by the batched jit and the sharded shard_map:
        encode + upload + histograms on a (K, N) stack (global for batched,
        the local shard for sharded — the encode is per-row, so the same
        body serves both).

        CSR family ("csr" / "csr_q"): compacts the deltas into the real
        wire payload rows, reconstructs the uploaded models from the
        payload (so what feeds histograms/aggregation is exactly what
        crossed the wire — csr_q reconstructs from the DEQUANTIZED decode),
        and — under EF — spills sub-threshold mass, capacity overflow and
        (csr_q) quantization error into the truncated residual. Returns
        (payload_tuple, stored, hists|None, res_payload|None,
        res_dense|None) where the payload tuple has ``self._payload_arity``
        components.

        Legacy dense-masked format returns (uploaded, nnz, hists|None,
        new_res|None) as before."""
        hist = self.histogram_batch
        if self._csr_wire:
            core = self.comm.csr_core(with_residual)

            def body(trained, base, xs, vs, residual=None):
                if with_residual:
                    payload, stored, decoded, res_payload, res_dense = \
                        core(trained, base, residual)
                else:
                    payload, stored, decoded = core(trained, base)
                    res_payload = res_dense = None
                hists = hist(base + decoded, xs, vs) if with_hist else None
                return payload, stored, hists, res_payload, res_dense

            return body
        core = self.comm.batch_core(with_residual) if self.comm.enabled \
            else None

        def body(trained, base, xs, vs, residual=None):
            if core is None:
                delta = trained - base
                if with_residual:
                    delta = delta + residual
                masked, nnz = delta, jnp.full((trained.shape[0],),
                                              trained.shape[1])
                new_res = jnp.zeros_like(delta) if with_residual else None
            elif with_residual:
                masked, nnz, new_res = core(trained, base, residual)
            else:
                masked, nnz = core(trained, base)
                new_res = None
            uploaded = base + masked
            hists = hist(uploaded, xs, vs) if with_hist else None
            return uploaded, nnz, hists, new_res

        return body

    def _distribute_encode_body(self):
        """Traced body shared by the batched jit and the sharded shard_map:
        sparse-encode the new global model against the (T, N) distribution
        target stack (per-row, so global and shard-local calls agree).
        Returns (new_base, nnz) — under the CSR format the new base is the
        decode of the actual compacted payload and ``nnz`` is the stored
        (on-wire) count."""
        if self._csr_wire:
            core = self.comm.csr_core(False)

            def body(new_flat, dist_base):
                g = jnp.broadcast_to(new_flat, dist_base.shape)
                _payload, stored, decoded = core(g, dist_base)
                return dist_base + decoded, stored

            return body
        core = self.comm.batch_core(False) if self.comm.enabled else None

        def body(new_flat, dist_base):
            g = jnp.broadcast_to(new_flat, dist_base.shape)
            if core is None:
                # disabled sparsification moves the dense model: the new
                # base is an exact copy (dist_base + (g - dist_base)
                # re-rounds; g itself does not)
                return g, jnp.full((dist_base.shape[0],), new_flat.shape[0])
            masked, nnz = core(g, dist_base)
            return dist_base + masked, nnz

        return body

    def _upload_fn(self, with_residual, with_hist):
        """encode (threshold/mask/count) + upload + histograms, one jit."""
        key = (with_residual, with_hist)
        fn = self._upload_jits.get(key)
        if fn is None:
            fn = jax.jit(self._encode_upload_body(with_residual, with_hist))
            self._upload_jits[key] = fn
        return fn

    def _upload_fn_paged(self, with_hist):
        """Paged-store batched upload under the CSR family: the gathered
        (K, rcap) residual window decodes to dense INSIDE the jit — fused
        with the encode, the dense (K, N) residual never crosses a stage
        boundary — and the new residual comes back as CSR pages for the
        writeback queue. The decode is a pure scatter of exact f32 values,
        so the result matches the resident dense-row path bit for bit."""
        key = ("paged", with_hist)
        fn = self._upload_jits.get(key)
        if fn is None:
            body = self._encode_upload_body(True, with_hist)
            n = self._global_flat.shape[0]

            @jax.jit
            def fn(trained, base, xs, vs, rvals, ridx):
                residual = csr_decode(rvals, ridx, n)
                payload, stored, hists, res_payload, _ = body(
                    trained, base, xs, vs, residual)
                return payload, stored, hists, res_payload[:2]

            self._upload_jits[key] = fn
        return fn

    def _finalize_fn(self):
        """server-flatten + weighted aggregation + distribute encode, one
        jit. Under the CSR format the aggregation consumes the upload
        payloads directly: the scatter-add decode is fused into the
        weighted client sum (``agg.blend_flat_csr``), so the dense uploaded
        stack never crosses the stage boundary.

        Versioned base store: the distribute half is the single
        chain-transition encode against R_r (no per-target stack — the jit
        never retraces on the round's target count, only on K). The dense
        store keeps the per-target encode over the (T, N) base stack
        (retraces per (participants, targets) shape pair)."""
        if self._finalize_jit is not None:
            return self._finalize_jit
        use_kernel = self.cfg.use_kernels
        versioned = self.base_store == "versioned"
        distribute = self._advance_encode_body() if versioned \
            else self._distribute_encode_body()

        if self._csr_wire:
            if self.wire_fmt == "csr_q":
                def blend(s, b, p, w, fw):
                    return agg.blend_flat_csr_q(s, b, *p, w, fw,
                                                use_kernel=use_kernel)
            else:
                def blend(s, b, p, w, fw):
                    return agg.blend_flat_csr(s, b, p[0], p[1], w, fw,
                                              use_kernel=use_kernel)

            @jax.jit
            def fn(server_flat, base_flat, payload, w, fw, dist_base):
                new_flat = blend(server_flat, base_flat, payload, w, fw)
                if versioned:
                    recon, payload = distribute(new_flat, dist_base)
                    return (new_flat, recon) + payload
                new_base, nnz = distribute(new_flat, dist_base)
                return new_flat, new_base, nnz
        else:
            @jax.jit
            def fn(server_flat, uploaded, w, fw, dist_base):
                if use_kernel:
                    from repro.kernels import ops as kops
                    unsup = kops.staleness_agg(uploaded, w)
                else:
                    unsup = jnp.einsum("k,kn->n", w, uploaded)
                new_flat = fw * server_flat + (1.0 - fw) * unsup
                if versioned:
                    recon, payload = distribute(new_flat, dist_base)
                    return (new_flat, recon) + payload
                new_base, nnz = distribute(new_flat, dist_base)
                return new_flat, new_base, nnz

        self._finalize_jit = fn
        return fn

    def _run_round_batched(self):
        """All participants per jitted stage: one training call (client axis
        inside), one upload encode+histogram call, one aggregate+distribute
        call. Zero per-message host syncs; one host transfer per round (the
        pseudo-label histograms feeding k-means grouping)."""
        cfg = self.cfg
        prev_time, ev, lrs = self._round_prologue()
        participants, stale, forced, t = ev
        r = self.global_version
        part_ids = [run.client for run in participants]
        K = len(part_ids)

        # same RNG stream as the sequential path: one split per participant
        # in arrival order, then the server's split
        keys = self._split_keys(K)

        # every client is padded to the fleet-wide max batch count, so the
        # epoch compiles exactly once; all-padding batches are skipped by
        # the in-graph cond, so each client still pays for exactly its own
        # number of optimizer steps
        xs, vs = self._gather_data(part_ids)
        if self.base_store == "versioned":
            # version-indexed base gather from the (tau+2, N) ring — no
            # per-client rows exist
            base_flat = self.store.gather(part_ids)
        else:
            base_flat = jnp.stack([self._base_rows[i] for i in part_ids])

        trained_flat, _ = self.batched_epoch(base_flat, xs, vs,
                                             lrs[part_ids], keys)

        with_hist = cfg.group_based and K > 1
        n = trained_flat.shape[1]
        if self._csr_wire:
            # the upload stage emits the compacted payload; the dense
            # uploaded stack never leaves the jit (histograms consume it
            # in-graph, aggregation takes base + payload)
            if cfg.error_feedback and self.paged:
                # residual pages in, residual pages out: the participant
                # window decodes to dense inside the jit (fused with the
                # encode) and the new CSR pages join the writeback queue
                rv, rx = self.cstore.gather_csr(part_ids)
                payload, nnz, hists_dev, (nrv, nrx) = self._upload_fn_paged(
                    with_hist)(trained_flat, base_flat, xs, vs, rv, rx)
                self.cstore.scatter_csr(part_ids, nrv, nrx)
            elif cfg.error_feedback:
                residual = jnp.stack(
                    [self._residual_rows[i] for i in part_ids])
                payload, nnz, hists_dev, _, res_dense = self._upload_fn(
                    True, with_hist)(trained_flat, base_flat, xs, vs,
                                     residual)
                for row, i in enumerate(part_ids):
                    self._residual_rows[i] = res_dense[row]
            else:
                payload, nnz, hists_dev, _, _ = self._upload_fn(
                    False, with_hist)(trained_flat, base_flat, xs, vs)
            self.comm.account_batch_csr(nnz, n, K)
        elif cfg.error_feedback and self.paged:
            residual = self.cstore.gather_dense(part_ids)
            uploaded_flat, nnz, hists_dev, residual = self._upload_fn(
                True, with_hist)(trained_flat, base_flat, xs, vs, residual)
            self.cstore.scatter_dense(part_ids, residual)
            self.comm.account_batch(nnz, n, K)
        elif cfg.error_feedback:
            residual = jnp.stack([self._residual_rows[i] for i in part_ids])
            uploaded_flat, nnz, hists_dev, residual = self._upload_fn(
                True, with_hist)(trained_flat, base_flat, xs, vs, residual)
            for row, i in enumerate(part_ids):
                self._residual_rows[i] = residual[row]
            self.comm.account_batch(nnz, n, K)
        else:
            uploaded_flat, nnz, hists_dev, _ = self._upload_fn(
                False, with_hist)(trained_flat, base_flat, xs, vs)
            self.comm.account_batch(nnz, n, K)

        # server supervised epoch on the current global model (Eq. 6), in
        # flat space; the RNG split order matches the sequential path
        self.rng, k = jax.random.split(self.rng)
        sp_flat, self.server_opt, _ = self.server_epoch_flat(
            self._global_flat, self.server_opt,
            self.data["server"]["x"], self.data["server"]["y"], cfg.lr, k)

        groups = None
        if with_hist:
            hists = np.asarray(hists_dev)
            groups = group_clients(hists, min(cfg.num_groups, K),
                                   seed=cfg.seed)

        fw = supervised_weight(r, C=cfg.C, M=self.M,
                               mode=cfg.supervised_weight_mode)
        w = agg.combine_weights(
            [len(self.data["clients"][i]["x"]) for i in part_ids],
            [stale[i] for i in part_ids], self.g_fn, groups)

        self.global_version += 1
        # distribution: latest + deprecated clients get the new model. All
        # participants are stale by construction (their base predates the
        # version bump), so fault-free the target set is never empty.
        if self.base_store == "versioned":
            # chain-delta broadcast: the finalize jit encodes ONE chain
            # transition against R_r; the store books the suffix from the
            # stalest target's version, each transition payload once
            prev = self.store.latest()
            if self._csr_wire:
                out = self._finalize_fn()(
                    sp_flat, base_flat, payload,
                    jnp.asarray(w, jnp.float32), jnp.float32(fw), prev)
            else:
                out = self._finalize_fn()(
                    sp_flat, uploaded_flat, jnp.asarray(w, jnp.float32),
                    jnp.float32(fw), prev)
            new_flat, recon, chain = out[0], out[1], out[2:]
            self._advance_versioned(recon, chain, ev, part_ids)
        else:
            targets, _ = self._distribution_plan(part_ids, ev)
            dist_base = jnp.stack([self._base_rows[i] for i in targets])
            if self._csr_wire:
                new_flat, new_base, nnz_d = self._finalize_fn()(
                    sp_flat, base_flat, payload,
                    jnp.asarray(w, jnp.float32), jnp.float32(fw), dist_base)
                self.comm.account_batch_csr(nnz_d, n, len(targets))
            else:
                new_flat, new_base, nnz_d = self._finalize_fn()(
                    sp_flat, uploaded_flat, jnp.asarray(w, jnp.float32),
                    jnp.float32(fw), dist_base)
                self.comm.account_batch(nnz_d, n, len(targets))
            for row, i in enumerate(targets):
                self._base_rows[i] = new_base[row]
            self._base_version[targets] = self.global_version
            self._reset_forced_residuals(forced)
        self._global_flat = new_flat
        self._gp_tree = None      # materialized lazily on demand

        return self._round_epilogue(prev_time, ev)

    # ------------------------------------------------------------------
    # chunked round body (core.param_layout): all engines stream the delta
    # pipeline one chunk at a time
    def _chunk_upload_fn(self, with_hist):
        """Upload-encode over the chunked parameter axis, one jit: the
        per-chunk encode loop is unrolled inside, so XLA's buffer liveness
        keeps one chunk's delta/decode temporaries (O(K * max_chunk)) live
        at a time. The base is a ring-gather CLOSURE ``(s, e) ->
        ring[:, s:e][slots]`` — no (K, N) base copy materializes for the
        encode. Returns (flat payload tuple [arity * num_chunks entries],
        stored_total (K,), hists | None, new residual pages | None)."""
        key = ("chunk", self.cfg.error_feedback, with_hist)
        fn = self._upload_jits.get(key)
        if fn is not None:
            return fn
        ef = self.cfg.error_feedback
        body = self.comm.chunk_encode_body(ef)
        plan = self.comm.chunk_plan()
        hist = self.histogram_batch

        def encode(trained, ring, slots, xs, vs, rvals, ridx):
            def base(s, e):
                return ring[:, s:e][slots]
            if ef:
                payloads, stored, decoded, (nrv, nri) = body(
                    trained, base, rvals, ridx)
            else:
                payloads, stored, decoded = body(trained, base)
                nrv = nri = None
            stored_total = stored[0]
            for st in stored[1:]:
                stored_total = stored_total + st
            hists = None
            if with_hist:
                # histograms need the full uploaded model for the forward
                # pass; build it by scattering each chunk's decode into the
                # gathered base (one (K, N) buffer, same as training held)
                up = ring[slots]
                for p, dec in zip(plan, decoded):
                    up = up.at[:, p["s"]:p["e"]].add(dec)
                hists = hist(up, xs, vs)
            flat_payload = tuple(x for pay in payloads for x in pay)
            return flat_payload, stored_total, hists, nrv, nri

        if ef:
            @jax.jit
            def fn(trained, ring, slots, xs, vs, rvals, ridx):
                return encode(trained, ring, slots, xs, vs, rvals, ridx)
        else:
            @jax.jit
            def fn(trained, ring, slots, xs, vs):
                return encode(trained, ring, slots, xs, vs, None, None)

        self._upload_jits[key] = fn
        return fn

    def _chunk_finalize_fn(self):
        """Chunked server blend + ring advance, one jit: each chunk's
        weighted client sum consumes that chunk's compacted payload against
        a per-chunk ring-gathered base (``agg.blend_flat_csr`` /
        ``_csr_q`` on (K, nc) slices — chunk-local indices decode in
        place), and the chain-transition encode streams the same chunks.
        The (K, N) uploaded stack of the flat finalize never exists."""
        if self._finalize_jit is not None:
            return self._finalize_jit
        plan = self.comm.chunk_plan()
        arity = self._payload_arity
        advance = self.comm.chunk_advance_body()
        quantized = self.wire_fmt == "csr_q"

        @jax.jit
        def fn(server_flat, ring, slots, payload, w, fw, prev):
            new = []
            for ci, p in enumerate(plan):
                s, e = p["s"], p["e"]
                pc = payload[ci * arity:(ci + 1) * arity]
                base_c = ring[:, s:e][slots]
                if quantized:
                    new_c = agg.blend_flat_csr_q(
                        server_flat[s:e], base_c, *pc, w, fw,
                        use_kernel=False)
                else:
                    new_c = agg.blend_flat_csr(
                        server_flat[s:e], base_c, pc[0], pc[1], w, fw,
                        use_kernel=False)
                new.append(new_c)
            new_flat = jnp.concatenate(new)
            recon, chain = advance(new_flat, prev)
            return (new_flat, recon) + chain

        self._finalize_jit = fn
        return fn

    def _train_sharded_chunked(self):
        """Train-only shard_map stage for chunked sharded rounds: each
        device trains its row shard from the replicated ring (client-local,
        no collectives). Encode/finalize then stream chunks unsharded —
        the chunked pipeline's O(K * chunk) liveness is the point; the
        training stage keeps the multi-device speedup."""
        fn = self._stage1_jits.get("chunk_train")
        if fn is not None:
            return fn
        mesh = self.mesh
        epoch = self.batched_epoch

        def shard_fn(ring, slots, xs, vs, lrs, keys):
            base = ring[slots]
            trained, _ = epoch(base, xs, vs, lrs, keys)
            return trained

        fn = jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(RING_SPEC, RING_SLOT_SPEC, _ROW3, _ROW2, _ROW, _ROW2),
            out_specs=_ROW2, check_rep=False))
        self._stage1_jits["chunk_train"] = fn
        return fn

    def _run_round_chunked(self):
        """One round streamed over the chunked parameter axis, shared by
        all three engines (the sequential engine runs the stacked epoch —
        same RNG stream, same per-client math; the sharded engine shards
        the training stage only). Encode, blend and ring advance all
        iterate chunks, so no stage materializes a (K, N) delta."""
        cfg = self.cfg
        prev_time, ev, lrs = self._round_prologue()
        participants, stale, forced, t = ev
        r = self.global_version
        part_ids = [run.client for run in participants]
        K = len(part_ids)
        n = self._global_flat.shape[0]

        # same RNG stream as the flat engines: one split per participant
        # in arrival order, then the server's split
        keys = self._split_keys(K)

        if self.engine == "sharded":
            D = self.mesh.devices.size
            Kp = padded_rows(K, D)
            pad = Kp - K
            pad_ids = part_ids + part_ids[:1] * pad
            xs, vs = self._gather_data(pad_ids)
            if pad:
                keys_p = jnp.concatenate(
                    [keys, jnp.zeros((pad,) + keys.shape[1:], keys.dtype)])
                # pad rows see no valid samples -> pure no-op epochs
                vs = vs * jnp.asarray(
                    np.concatenate([np.ones(K, np.float32),
                                    np.zeros(pad, np.float32)]))[:, None]
            else:
                keys_p = keys
            lrs_p = jnp.asarray(
                np.concatenate([lrs[part_ids], np.zeros(pad)]), jnp.float32)
            slots_p = self.store.slots_for(pad_ids)
            trained = self._train_sharded_chunked()(
                self.store.ring, slots_p, xs, vs, lrs_p, keys_p)
            trained = trained[:K]
            xs, vs = xs[:K], vs[:K]
            slots = slots_p[:K]
        else:
            xs, vs = self._gather_data(part_ids)
            slots = self.store.slots_for(part_ids)
            base_flat = self.store.gather(part_ids)
            trained, _ = self.batched_epoch(base_flat, xs, vs,
                                            lrs[part_ids], keys)

        with_hist = cfg.group_based and K > 1
        upload = self._chunk_upload_fn(with_hist)
        if cfg.error_feedback:
            if self.paged:
                rv, rx = self.cstore.gather_csr(part_ids)
            else:
                idxK = jnp.asarray(part_ids)
                rv = _gather_rows(self._res_vals, idxK)
                rx = _gather_rows(self._res_idx, idxK)
            payload, stored_total, hists_dev, nrv, nri = upload(
                trained, self.store.ring, slots, xs, vs, rv, rx)
            if self.paged:
                self.cstore.scatter_csr(part_ids, nrv, nri)
            else:
                self._res_vals = _scatter_rows(self._res_vals, idxK, nrv)
                self._res_idx = _scatter_rows(self._res_idx, idxK, nri)
        else:
            payload, stored_total, hists_dev, _, _ = upload(
                trained, self.store.ring, slots, xs, vs)
        # one ledger entry for the whole chunked batch; the layout-aware
        # framing (per-chunk row_ptr, scales, block tables) is booked by
        # the comm channel's chunk-aware accounting
        self.comm.account_batch_csr(stored_total, n, K)

        # server supervised epoch on the current global model (Eq. 6), in
        # flat space; the RNG split order matches the flat engines
        self.rng, k = jax.random.split(self.rng)
        sp_flat, self.server_opt, _ = self.server_epoch_flat(
            self._global_flat, self.server_opt,
            self.data["server"]["x"], self.data["server"]["y"], cfg.lr, k)

        groups = None
        if with_hist:
            hists = np.asarray(hists_dev)
            groups = group_clients(hists, min(cfg.num_groups, K),
                                   seed=cfg.seed)

        fw = supervised_weight(r, C=cfg.C, M=self.M,
                               mode=cfg.supervised_weight_mode)
        w = agg.combine_weights(
            [len(self.data["clients"][i]["x"]) for i in part_ids],
            [stale[i] for i in part_ids], self.g_fn, groups)

        self.global_version += 1
        prev = self.store.latest()
        out = self._chunk_finalize_fn()(
            sp_flat, self.store.ring, slots, payload,
            jnp.asarray(w, jnp.float32), jnp.float32(fw), prev)
        new_flat, recon, chain = out[0], out[1], out[2:]
        self._advance_versioned(recon, chain, ev, part_ids)
        self._global_flat = new_flat
        self._gp_tree = None      # materialized lazily on demand

        return self._round_epilogue(prev_time, ev)

    def peak_delta_device_bytes(self):
        """Analytic peak DEVICE bytes of one round's delta pipeline: the
        widest live set any encode/blend stage holds for the k = ceil(C*M)
        expected participants. Flat path: delta + decode (K, N) f32 pairs
        (plus the EF residual expansion and spill under error feedback) and
        the (K, cap) f32+int32 payload. Chunked: the same buffers at
        max_chunk width — O(K * chunk), flat in N, which is the number the
        bench/regression gate pins across model sizes."""
        k = max(int(np.ceil(self.cfg.C * self.M)), 1)
        n = self._global_flat.shape[0]
        if self.chunked:
            chunk = self.layout.max_chunk
            cap = max(p["cap"] for p in self.comm.chunk_plan())
        else:
            chunk = n
            cap = self.comm.payload_capacity(n) if self._csr_wire else n
        bufs = 2 + (2 if self.cfg.error_feedback else 0)
        return int(4 * k * chunk * bufs + 8 * k * cap)

    # ------------------------------------------------------------------
    # sharded fleet engine: shard_map over the ``clients`` mesh axis
    def _stage1_sharded(self, with_residual, with_hist):
        """Train + upload-encode (+ pseudo-label histograms), one jitted
        shard_map per participant-shape: each device trains its row shard
        of the (Kp, N) stack and sparsifies the deltas against local
        per-client quantile thresholds. Entirely client-local — the stage
        has no collectives.

        Versioned base store: the stage takes the replicated (tau+2, N)
        reconstruction ring plus the sharded per-client slot vector and
        gathers each shard's base rows locally (``ring[slots]``) — the
        (Kp, N) base stack never materializes outside the stage. The dense
        store passes the pre-gathered (Kp, N) rows as before."""
        key = (with_residual, with_hist)
        fn = self._stage1_jits.get(key)
        if fn is not None:
            return fn
        mesh = self.mesh
        epoch = self.batched_epoch
        encode_upload = self._encode_upload_body(with_residual, with_hist)
        placeholder = jnp.zeros((), jnp.float32)       # shard_map needs
                                                       # arrays, not Nones
        _PV, _PI, _PC = CLIENT_PAYLOAD_SPECS
        versioned = self.base_store == "versioned"
        base_specs = (RING_SPEC, RING_SLOT_SPEC) if versioned else (_ROW2,)

        if self._csr_wire:
            n = self._global_flat.shape[0]
            # wire payload specs vary by format (csr: 2, csr_q: 4); the EF
            # residual store stays f32 CSR rows regardless of what's on the
            # wire, so its specs are always the f32 pair
            pspecs = payload_specs(self.wire_fmt)

            def shard_fn(*args):
                if versioned:
                    ring, slots = args[:2]
                    base = ring[slots]
                    xs, vs, lrs, keys, rvals, ridx = args[2:]
                else:
                    base, xs, vs, lrs, keys, rvals, ridx = args
                trained, _ = epoch(base, xs, vs, lrs, keys)
                # the residual store arrives as CSR rows; expand the local
                # shard to dense only inside the stage (per-row scatter)
                residual = csr_decode(rvals, ridx, n) if with_residual \
                    else None
                payload, stored, hists, res_payload, _ = encode_upload(
                    trained, base, xs, vs, residual)
                rp = res_payload if with_residual else (placeholder,) * 2
                return payload + (stored,
                                  hists if with_hist else placeholder,
                                  rp[0], rp[1])

            in_specs = base_specs + (_ROW3, _ROW2, _ROW, _ROW2,
                                     _PV if with_residual else _REP,
                                     _PI if with_residual else _REP)
            out_specs = pspecs + (_PC,
                                  _ROW2 if with_hist else _REP,
                                  _PV if with_residual else _REP,
                                  _PI if with_residual else _REP)
            fn = jax.jit(shard_map(
                shard_fn, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False))
            self._stage1_jits[key] = fn
            return fn

        def shard_fn(*args):
            if versioned:
                ring, slots = args[:2]
                base = ring[slots]
                xs, vs, lrs, keys, residual = args[2:]
            else:
                base, xs, vs, lrs, keys, residual = args
            trained, _ = epoch(base, xs, vs, lrs, keys)
            uploaded, nnz, hists, new_res = encode_upload(
                trained, base, xs, vs, residual if with_residual else None)
            return (uploaded, nnz,
                    hists if with_hist else placeholder,
                    new_res if with_residual else placeholder)

        out_specs = (_ROW2, _ROW,
                     _ROW2 if with_hist else _REP,
                     _ROW2 if with_residual else _REP)
        fn = jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=base_specs + (_ROW3, _ROW2, _ROW, _ROW2,
                                   _ROW2 if with_residual else _REP),
            out_specs=out_specs, check_rep=False))
        self._stage1_jits[key] = fn
        return fn

    def _group_weights_sharded(self, K, num_groups, Kp):
        """On-device grouping + Eq. 10 weights: jitted k-means over the
        participants' pseudo-label histograms feeding the grouped weight
        fold, padded to the sharded row count — the host sync the batched
        engine pays for numpy k-means disappears."""
        key = (K, num_groups, Kp)
        fn = self._groupw_jits.get(key)
        if fn is not None:
            return fn
        init_idx = init_index(K, self.cfg.seed)

        @jax.jit
        def fn(hists, size_g):
            assign, _ = kmeans_device(hists[:K], num_groups,
                                      init_idx=init_idx)
            w = agg.combine_weights_device(size_g, assign, num_groups)
            return jnp.zeros((Kp,), jnp.float32).at[:K].set(w)

        self._groupw_jits[key] = fn
        return fn

    def _stage2_sharded(self):
        """Aggregate + distribute under shard_map: the weighted client sum
        is one psum over the client axis (pad rows carry weight zero) and
        the f(r) blend replicates. Dense store: each device then sparsifies
        the distribution deltas for its shard of the target rows. Versioned
        store: every device runs the identical single chain-transition
        encode against the replicated R_r (no per-target work at all)."""
        fn = self._stage2_jits.get("finalize")
        if fn is not None:
            return fn
        mesh = self.mesh
        use_kernel = self.cfg.use_kernels
        versioned = self.base_store == "versioned"
        distribute = self._advance_encode_body() if versioned \
            else self._distribute_encode_body()
        # payload arity of the advance encode (CSR-family wire tuple +
        # stored / nnz / exact)
        n_payload = self._payload_arity + 1 if self._csr_wire else \
            (1 if self.comm.enabled else 0)

        if self._csr_wire:
            pspecs = payload_specs(self.wire_fmt)
            if self.wire_fmt == "csr_q":
                def blend(s, b, p, w, fw):
                    return agg.blend_flat_sharded_csr_q(
                        s, b, *p, w, fw,
                        axis_name=CLIENT_AXIS, use_kernel=use_kernel)
            else:
                def blend(s, b, p, w, fw):
                    return agg.blend_flat_sharded_csr(
                        s, b, p[0], p[1], w, fw,
                        axis_name=CLIENT_AXIS, use_kernel=use_kernel)

            if versioned:
                def shard_fn(server_flat, ring, slots, payload, w, fw,
                             prev):
                    base = ring[slots]
                    new_flat = blend(server_flat, base, payload, w, fw)
                    recon, chain = distribute(new_flat, prev)
                    return (new_flat, recon) + chain

                fn = jax.jit(shard_map(
                    shard_fn, mesh=mesh,
                    in_specs=(_REP, RING_SPEC, RING_SLOT_SPEC, pspecs,
                              _ROW, _REP, _REP),
                    out_specs=(_REP, _REP) + (_REP,) * n_payload,
                    check_rep=False))
                self._stage2_jits["finalize"] = fn
                return fn

            def shard_fn(server_flat, base, payload, w, fw, dist_base):
                new_flat = blend(server_flat, base, payload, w, fw)
                new_base, nnz = distribute(new_flat, dist_base)
                return new_flat, new_base, nnz

            fn = jax.jit(shard_map(
                shard_fn, mesh=mesh,
                in_specs=(_REP, _ROW2, pspecs, _ROW, _REP, _ROW2),
                out_specs=(_REP, _ROW2, _ROW), check_rep=False))
            self._stage2_jits["finalize"] = fn
            return fn

        if versioned:
            def shard_fn(server_flat, uploaded, w, fw, prev):
                new_flat = agg.blend_flat_sharded(
                    server_flat, uploaded, w, fw,
                    axis_name=CLIENT_AXIS, use_kernel=use_kernel)
                recon, payload = distribute(new_flat, prev)
                return (new_flat, recon) + payload

            fn = jax.jit(shard_map(
                shard_fn, mesh=mesh,
                in_specs=(_REP, _ROW2, _ROW, _REP, _REP),
                out_specs=(_REP, _REP) + (_REP,) * n_payload,
                check_rep=False))
            self._stage2_jits["finalize"] = fn
            return fn

        def shard_fn(server_flat, uploaded, w, fw, dist_base):
            new_flat = agg.blend_flat_sharded(
                server_flat, uploaded, w, fw,
                axis_name=CLIENT_AXIS, use_kernel=use_kernel)
            new_base, nnz = distribute(new_flat, dist_base)
            return new_flat, new_base, nnz

        fn = jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(_REP, _ROW2, _ROW, _REP, _ROW2),
            out_specs=(_REP, _ROW2, _ROW), check_rep=False))
        self._stage2_jits["finalize"] = fn
        return fn

    def _run_round_sharded(self):
        """One fleet round: gather participant rows, one sharded
        train+upload stage, the replicated server epoch, on-device
        grouping/weights, one sharded aggregate+distribute stage, scatter
        the new base rows back. Zero per-round host syncs (the deferred
        ACO read excepted); K is padded to the device count with
        zero-weight rows that are sliced off before accounting."""
        cfg = self.cfg
        prev_time, ev, lrs = self._round_prologue()
        participants, stale, forced, t = ev
        r = self.global_version
        part_ids = [run.client for run in participants]
        K = len(part_ids)
        D = self.mesh.devices.size
        Kp = padded_rows(K, D)
        pad = Kp - K

        # same RNG stream as the sequential path: one split per REAL
        # participant in arrival order, then the server's split
        keys = self._split_keys(K)
        pad_ids = part_ids + part_ids[:1] * pad
        idx = jnp.asarray(pad_ids)
        xs, vs = self._gather_data(pad_ids)
        if pad:
            keys = jnp.concatenate([keys, jnp.zeros((pad,) + keys.shape[1:],
                                                    keys.dtype)])
            # pad rows see no valid samples -> their epoch is a pure no-op
            vs = vs * jnp.asarray(
                np.concatenate([np.ones(K, np.float32),
                                np.zeros(pad, np.float32)]))[:, None]
        lrs_p = jnp.asarray(np.concatenate([lrs[part_ids], np.zeros(pad)]),
                            jnp.float32)
        if self.base_store == "versioned":
            # the base rows are gathered from the replicated (tau+2, N)
            # ring inside the stages; only the slot vector crosses in
            slots = self.store.slots_for(pad_ids)
            base_args = (self.store.ring, slots)
        else:
            base_args = (_gather_rows(self._base_mat, idx),)
        n = self._global_flat.shape[0]

        with_hist = cfg.group_based and K > 1
        stage1 = self._stage1_sharded(cfg.error_feedback, with_hist)
        if self._csr_wire:
            arity = self._payload_arity
            if cfg.error_feedback:
                # residual rows travel as CSR (values, indices) — the dense
                # (M, N) residual matrix no longer exists. Paged store: the
                # (Kp, rcap) window comes off the host pages instead of a
                # device (M, rcap) gather; the stage is unchanged (it
                # already consumes participant windows)
                if self.paged:
                    rvals, ridx = self.cstore.gather_csr(pad_ids)
                else:
                    rvals = _gather_rows(self._res_vals, idx)
                    ridx = _gather_rows(self._res_idx, idx)
                out = stage1(*base_args, xs, vs, lrs_p, keys, rvals, ridx)
                nrv, nri = out[arity + 2], out[arity + 3]
                if self.paged:
                    self.cstore.scatter_csr(part_ids, nrv[:K], nri[:K])
                else:
                    self._res_vals = _scatter_rows(self._res_vals, idx[:K],
                                                   nrv[:K])
                    self._res_idx = _scatter_rows(self._res_idx, idx[:K],
                                                  nri[:K])
            else:
                z = jnp.zeros((), jnp.float32)
                out = stage1(*base_args, xs, vs, lrs_p, keys, z, z)
            payload, nnz, hists_dev = \
                tuple(out[:arity]), out[arity], out[arity + 1]
            self.comm.account_batch_csr(nnz[:K], n, K)
        elif cfg.error_feedback:
            residual = self.cstore.gather_dense(pad_ids) if self.paged \
                else _gather_rows(self._residual_mat, idx)
            uploaded, nnz, hists_dev, new_res = stage1(
                *base_args, xs, vs, lrs_p, keys, residual)
            if self.paged:
                self.cstore.scatter_dense(part_ids, new_res[:K])
            else:
                self._residual_mat = _scatter_rows(
                    self._residual_mat, idx[:K], new_res[:K])
            self.comm.account_batch(nnz[:K], n, K)
        else:
            uploaded, nnz, hists_dev, _ = stage1(
                *base_args, xs, vs, lrs_p, keys, jnp.zeros((), jnp.float32))
            self.comm.account_batch(nnz[:K], n, K)

        # server supervised epoch on the current global model (Eq. 6), in
        # flat space; the RNG split order matches the sequential path
        self.rng, k = jax.random.split(self.rng)
        sp_flat, self.server_opt, _ = self.server_epoch_flat(
            self._global_flat, self.server_opt,
            self.data["server"]["x"], self.data["server"]["y"], cfg.lr, k)

        sizes = [len(self.data["clients"][i]["x"]) for i in part_ids]
        stales = [stale[i] for i in part_ids]
        if with_hist:
            size_g = np.asarray(sizes, np.float64) * \
                np.array([self.g_fn(s) for s in stales])
            w_pad = self._group_weights_sharded(
                K, min(cfg.num_groups, K), Kp)(
                    hists_dev, jnp.asarray(size_g, jnp.float32))
        else:
            w = agg.combine_weights(sizes, stales, self.g_fn, None)
            w_pad = jnp.asarray(np.concatenate([w, np.zeros(pad)]),
                                jnp.float32)

        fw = supervised_weight(r, C=cfg.C, M=self.M,
                               mode=cfg.supervised_weight_mode)
        self.global_version += 1
        # distribution: latest + deprecated clients get the new model
        if self.base_store == "versioned":
            # chain-delta broadcast: one replicated chain-transition encode
            # in the stage; the store books the suffix from the stalest
            # target's version (each transition payload once) — no
            # per-target rows, gathers or retraces on the target count
            prev = self.store.latest()
            if self._csr_wire:
                out = self._stage2_sharded()(
                    sp_flat, self.store.ring, slots, payload, w_pad,
                    jnp.float32(fw), prev)
            else:
                out = self._stage2_sharded()(
                    sp_flat, uploaded, w_pad, jnp.float32(fw), prev)
            new_flat, recon, chain = out[0], out[1], out[2:]
            self._advance_versioned(recon, chain, ev, part_ids)
        else:
            targets, _ = self._distribution_plan(part_ids, ev)
            T = len(targets)
            Tp = padded_rows(T, D)
            tidx = jnp.asarray(targets + targets[:1] * (Tp - T))
            dist_base = _gather_rows(self._base_mat, tidx)
            if self._csr_wire:
                new_flat, new_base, nnz_d = self._stage2_sharded()(
                    sp_flat, base_args[0], payload, w_pad,
                    jnp.float32(fw), dist_base)
                self.comm.account_batch_csr(nnz_d[:T], n, T)
            else:
                new_flat, new_base, nnz_d = self._stage2_sharded()(
                    sp_flat, uploaded, w_pad, jnp.float32(fw), dist_base)
                self.comm.account_batch(nnz_d[:T], n, T)
            self._base_mat = _scatter_rows(self._base_mat, tidx[:T],
                                           new_base[:T])
            self._base_version[targets] = self.global_version
            self._reset_forced_residuals(forced)
        self._global_flat = new_flat
        self._gp_tree = None      # materialized lazily on demand

        return self._round_epilogue(prev_time, ev)

    # ------------------------------------------------------------------
    def base_store_bytes(self):
        """Bytes of server-side per-client base-model state (counterpart to
        ``residual_store_bytes``). The versioned store is O(tau * N + M):
        the (tau+2, N) reconstruction ring + retained chain payloads + the
        per-client version array. The legacy dense layouts are O(M * N)
        (per-client trees / rows / the (M, N) matrix) — the fleet-scale
        memory the versioned store removes."""
        if self.base_store == "versioned":
            return self.store.bytes()
        if self.engine == "sharded":
            return int(self._base_mat.size * 4) + self._base_version.nbytes
        if self.engine == "batched":
            # rows may alias (clients at the same version share buffers
            # until a distribution diverges them); report the logical
            # footprint, matching what a real parameter server would hold
            return int(sum(r.size * 4 for r in self._base_rows)) \
                + self._base_version.nbytes
        return int(sum(
            sum(leaf.size * 4 for leaf in jax.tree.leaves(c["base_params"]))
            for c in self.clients)) + 8 * self.M

    def residual_store_bytes(self):
        """Bytes held by the per-client error-feedback residual state (0
        when EF is off). The sharded CSR store is O(M * rcap); the legacy
        dense layouts are O(M * N) — the fleet-scale memory the compacted
        format removes."""
        if not self.cfg.error_feedback:
            return 0
        if self.paged:
            # host-nominal bytes of the residual pages (lazily committed /
            # memmapped); the device-side share is in
            # ``client_state_device_bytes``
            return self.cstore.residual_store_bytes()
        if self.chunked:
            return int((self._res_vals.size + self._res_idx.size) * 4)
        if self.engine == "sharded":
            if self._csr_wire:
                return int((self._res_vals.size + self._res_idx.size) * 4)
            return int(self._residual_mat.size * 4)
        if self.engine == "batched":
            return int(sum(r.size * 4 for r in self._residual_rows))
        return int(sum(
            sum(leaf.size * 4 for leaf in jax.tree.leaves(c["residual"]))
            for c in self.clients if "residual" in c))

    def client_state_device_bytes(self):
        """DEVICE-resident bytes of per-client state: EF residual storage
        plus (for the stacked engines) the padded data stack. Resident
        layouts hold (M, ...) arrays — linear in the fleet size; the paged
        store holds only the last round's participant window and its
        pending writeback pages — O(K * page), flat in M. This is the
        number the CI scale gate pins flat across fleet sizes."""
        if self.paged:
            return self.cstore.device_window_bytes() \
                + self._data_window_bytes
        total = 0
        if self.batched:
            total += int(self._x_pad.nbytes + self._valid_pad.nbytes)
        if self.cfg.error_feedback:
            if self.chunked:
                total += int((self._res_vals.size
                              + self._res_idx.size) * 4)
            elif self.engine == "sharded":
                if self._csr_wire:
                    total += int((self._res_vals.size
                                  + self._res_idx.size) * 4)
                else:
                    total += int(self._residual_mat.size * 4)
            elif self.engine == "batched":
                total += int(sum(r.size * 4 for r in self._residual_rows))
            else:
                total += self.residual_store_bytes()
        return total

    def client_state_host_bytes(self):
        """HOST-resident bytes of per-client state (nominal): the paged
        store's pages + counters + adopted version arrays, plus the host
        copy of the padded data stack the stacked engines page from. The
        resident layouts keep versions host-side (the versioned base
        store) and everything else on device."""
        if self.paged:
            total = self.cstore.host_bytes()
            if self.batched:
                total += int(self._x_pad_h.nbytes + self._valid_pad_h.nbytes
                             + self._data_map.nbytes)
            return total
        if self.base_store == "versioned":
            return int(self.store.client_version.nbytes
                       + self.store.detached.nbytes)
        if self.batched:
            return int(np.asarray(self._base_version).nbytes)
        return 8 * self.M

    def client_state_resident_equiv_bytes(self):
        """What the resident layout would put on DEVICE at this fleet size:
        the (M, nb*B, F) padded data stack (stacked engines) plus the
        (M, rcap) CSR or (M, n) dense residual store under EF. The scale
        gate requires ``client_state_device_bytes`` strictly below this on
        every paged cell — at M=1,000,000 the resident equivalent simply
        would not fit."""
        total = 0
        if self.batched:
            if self.paged:
                total += self.M * self._data_row_bytes
            else:
                total += int(self._x_pad.nbytes + self._valid_pad.nbytes)
        if self.cfg.error_feedback:
            n = self._global_flat.shape[0]
            if self._csr_wire:
                rcap = self.comm.residual_capacity_total() if self.chunked \
                    else self.comm.residual_capacity(n)
                total += self.M * rcap * 8
            else:
                total += self.M * n * 4
        return total

    # ------------------------------------------------------------------
    # crash-consistent checkpointing (core.fleet_ckpt)
    def _ef_kind(self):
        """Which serialized form this trainer's EF residual state takes
        (part of the checkpoint fingerprint: the layouts are engine-
        specific and do not cross-load)."""
        if not self.cfg.error_feedback:
            return "none"
        if self.paged:
            return "paged"            # pages ride in the cstore section
        if self.chunked or (self.engine == "sharded" and self._csr_wire):
            return "csr"
        if self.engine == "sharded":
            return "dense_mat"
        if self.engine == "batched":
            return "rows"
        return "trees"

    def _ef_state(self):
        """Device-resident EF snapshot; ``save_checkpoint`` batches the
        host transfer for all layouts in one ``jax.device_get``."""
        kind = self._ef_kind()
        if kind == "csr":
            return {"kind": kind, "vals": self._res_vals,
                    "idx": self._res_idx}
        if kind == "dense_mat":
            return {"kind": kind, "mat": self._residual_mat}
        if kind == "rows":
            rows = tuple(self._residual_rows)   # immutable device refs
            # host-side stack: the writer thread must never LAUNCH device
            # programs (a jnp.stack dispatched concurrently with the main
            # thread's multi-device round program can interleave collective
            # rendezvous across the two programs and deadlock XLA:CPU) —
            # np.asarray is a pure transfer, np.stack is host memcpy
            return {"kind": kind,
                    "rows": fleet_ckpt.Lazy(
                        lambda: np.stack([np.asarray(r) for r in rows]))}
        if kind == "trees":
            items = [[int(i), list(jax.tree.leaves(c["residual"]))]
                     for i, c in enumerate(self.clients)
                     if "residual" in c]
            return {"kind": kind, "items": items}
        return {"kind": kind}

    def _load_ef_state(self, d):
        kind = self._ef_kind()
        if d["kind"] != kind:
            raise ValueError(f"checkpoint EF state is {d['kind']!r}, this "
                             f"trainer stores {kind!r}")
        if kind == "csr":
            self._res_vals = jnp.asarray(np.asarray(d["vals"], np.float32))
            self._res_idx = jnp.asarray(np.asarray(d["idx"], np.int32))
        elif kind == "dense_mat":
            self._residual_mat = jnp.asarray(np.asarray(d["mat"],
                                                        np.float32))
        elif kind == "rows":
            rows = jnp.asarray(np.asarray(d["rows"], np.float32))
            self._residual_rows = [rows[i] for i in range(rows.shape[0])]
        elif kind == "trees":
            tmpl, treedef = jax.tree_util.tree_flatten(self._template)
            for c in self.clients:
                c.pop("residual", None)
            for i, leaves in d["items"]:
                self.clients[int(i)]["residual"] = \
                    jax.tree_util.tree_unflatten(treedef, [
                        jnp.asarray(np.asarray(l), t.dtype)
                        for l, t in zip(leaves, tmpl)])

    def _ckpt_fingerprint(self):
        """Config/layout identity a checkpoint must match to restore: the
        mutable state's meaning depends on all of it (the ParamLayout
        chunking via the chunk plan, the wire format via payload shapes,
        the engine via the EF layout, the seed via every RNG stream)."""
        cfg = self.cfg
        chunks = [[int(p["s"]), int(p["e"])]
                  for p in self.comm.chunk_plan()] if self.chunked else None
        return {"format": fleet_ckpt.FORMAT_VERSION,
                "M": int(self.M), "n": int(self._global_flat.shape[0]),
                "engine": self.engine, "wire_fmt": self.wire_fmt,
                "q_dtype": str(cfg.q_dtype),
                "base_store": self.base_store,
                "client_store": str(cfg.client_store),
                "error_feedback": bool(cfg.error_feedback),
                "ef_kind": self._ef_kind(),
                "tau": int(cfg.tau), "C": float(cfg.C),
                "seed": int(cfg.seed),
                "sparse_comm": bool(cfg.sparse_comm),
                "sparse_threshold": str(cfg.sparse_threshold),
                "chunks": chunks}

    def _ckpt_drain(self):
        """Wait for the in-flight background checkpoint write, if any,
        and re-raise whatever it failed with."""
        if self._ckpt_queue is not None:
            self._ckpt_queue.join()
        if self._ckpt_exc is not None:
            exc, self._ckpt_exc = self._ckpt_exc, None
            raise exc

    def _ckpt_submit(self, job):
        """Hand ``job`` to the persistent checkpoint writer thread
        (started lazily; spawning a thread per save costs milliseconds).
        Exceptions surface on the next :meth:`_ckpt_drain`."""
        if self._ckpt_thread is None:
            self._ckpt_queue = queue.Queue()

            def _loop(q=self._ckpt_queue):
                while True:
                    j = q.get()
                    try:
                        j()
                    except BaseException as exc:
                        self._ckpt_exc = exc
                    finally:
                        q.task_done()

            self._ckpt_thread = threading.Thread(
                target=_loop, name="fleet-ckpt-writer", daemon=True)
            self._ckpt_thread.start()
        self._ckpt_queue.put(job)

    def _ckpt_sections(self):
        """Snapshot every checkpoint section on the CALLING thread.
        Device-resident tensors are captured by reference — JAX arrays
        are immutable, so the writer thread can transfer and serialize
        them later with no consistency risk — while everything mutable
        on the host (participation matrix, scheduler/store/ledger state,
        the log history) is copied or frozen to bytes here. Round logs
        are append-only and never mutate once their round has closed, so
        each is packed exactly once per run and the section is assembled
        from cached bytes (re-encoding the whole history made save cost
        grow linearly with the round index)."""
        flat = self._global_flat if self._gp_tree is None \
            else flatten_tree(self._gp_tree)
        # capture the new logs by reference; the writer thread packs them
        # into the shared cache (exclusive: at most one write in flight,
        # and the training thread only touches the cache after a drain)
        cache = self._log_pack
        new_logs = self.logs[len(cache):]

        def _logs_bytes():
            for log in new_logs:
                cache.append(fleet_ckpt.pack(vars(log)))
            return fleet_ckpt.pack_array_of_packed(cache)

        sections = {
            "trainer": {
                "round": int(self.global_version),
                "rng": self.rng,
                "global_flat": flat,
                "server_opt": list(jax.tree.leaves(self.server_opt)),
                "participation": self.participation.copy(),
                "ef": self._ef_state(),
            },
            "scheduler": self.scheduler.state_dict(),
            # defer=True: the snapshot must not block on the round's
            # still-in-flight device work — the writer thread resolves
            # the Lazy folds (bit-identical to the eager path)
            "store": self.store.state_dict(defer=True),
            "comm": self.comm.ledger_state(defer=True),
            "logs": fleet_ckpt.PrePacked(_logs_bytes),
        }
        if self.paged:
            sections["cstore"] = self.cstore.state_dict()
        return sections

    def save_checkpoint(self, *, wait=True):
        """Write one crash-consistent checkpoint of the COMPLETE round-
        boundary state: global model + server Adam state, EF residuals,
        the versioned base store (ring, chain, versions, detached mask),
        paged client pages, scheduler heaps + fault-RNG positions, comm
        ledgers, participation matrix and round logs — committed by a
        checksummed MANIFEST written tmp+fsync+rename LAST, so a crash
        mid-write leaves the previous good checkpoint restorable.

        With ``wait=False`` the host transfer, serialization and disk
        protocol run on a background writer thread (at most one in
        flight; a new save or :meth:`restore` joins it first), keeping
        the training loop's exposure to a few hundred microseconds of
        snapshotting — ``train()`` checkpoints this way. Errors from a
        background write surface on the next save/drain. Returns the
        checkpoint directory path."""
        root = self.cfg.checkpoint_dir
        if not root:
            raise ValueError(
                "save_checkpoint() needs FedS3AConfig(checkpoint_dir=...)")
        self._ckpt_drain()
        rnd = int(self.global_version)
        sections = self._ckpt_sections()
        fingerprint = self._ckpt_fingerprint()

        def _write():
            # one batched host transfer for every device-resident tensor
            # (per-leaf np.asarray would pay a dispatch+sync each); this
            # also absorbs the wait for the round's still-in-flight async
            # dispatch, which is the bulk of a synchronous save's cost
            return fleet_ckpt.write_checkpoint(
                root, rnd, jax.device_get(sections), fingerprint)

        if wait:
            return _write()
        self._ckpt_submit(_write)
        return os.path.join(root, f"ckpt-{rnd:08d}")

    def restore(self, checkpoint_dir=None):
        """Resume from the newest restorable checkpoint (torn writes fall
        back to the previous good one). Call on a freshly constructed
        trainer with the same data and config as the writer — the
        fingerprint is validated — then ``train()`` continues bit-exactly
        where the checkpoint left off: schedules, metrics, ACO, fault
        traces and fleet health all match an uninterrupted run. Returns
        the restored round index."""
        self._ckpt_drain()
        root = checkpoint_dir if checkpoint_dir is not None \
            else self.cfg.checkpoint_dir
        if not root:
            raise ValueError("restore() needs a checkpoint directory")
        path, manifest = fleet_ckpt.find_restorable(root)
        if path is None:
            raise FileNotFoundError(
                f"no restorable checkpoint under {root!r}")
        fp = self._ckpt_fingerprint()
        if manifest.get("fingerprint") != fp:
            raise ValueError(
                "checkpoint fingerprint mismatch: the checkpoint was "
                "written under a different configuration/layout than this "
                "trainer's")
        tr = fleet_ckpt.read_section(path, "trainer")
        self.global_version = int(tr["round"])
        self.rng = jnp.asarray(np.asarray(tr["rng"]), jnp.uint32)
        self._global_flat = jnp.asarray(np.asarray(tr["global_flat"]),
                                        jnp.float32)
        self._gp_tree = None
        leaves, treedef = jax.tree_util.tree_flatten(self.server_opt)
        if len(tr["server_opt"]) != len(leaves):
            raise ValueError(
                f"checkpoint server_opt has {len(tr['server_opt'])} "
                f"leaves, expected {len(leaves)}")
        self.server_opt = jax.tree_util.tree_unflatten(treedef, [
            jnp.asarray(np.asarray(s).reshape(np.shape(t)),
                        jnp.asarray(t).dtype)
            for s, t in zip(tr["server_opt"], leaves)])
        self.participation = np.asarray(
            tr["participation"], np.float64).reshape(-1, self.M)
        self._load_ef_state(tr["ef"])
        self.scheduler.load_state_dict(
            fleet_ckpt.read_section(path, "scheduler"))
        self.store.load_state_dict(fleet_ckpt.read_section(path, "store"))
        self.comm.load_ledger_state(fleet_ckpt.read_section(path, "comm"))
        if self.paged:
            self.cstore.load_state_dict(
                fleet_ckpt.read_section(path, "cstore"))
            # the store's load reassigned its version arrays; re-adopt the
            # references so host-byte reporting tracks the live objects
            self.cstore.adopt_versions(self.store.client_version,
                                       self.store.detached)
        self.logs = []
        self._log_pack = []
        for d in fleet_ckpt.read_section(path, "logs"):
            d = dict(d)
            d["stalenesses"] = {int(k): float(v)
                                for k, v in d["stalenesses"].items()}
            self.logs.append(RoundLog(**d))
        self._data_window_bytes = 0
        return int(tr["round"])

    def evaluate(self, params=None):
        params = params if params is not None else self.global_params
        test = self.data["test"]
        preds = np.asarray(self.predict(params, jnp.asarray(test["x"])))
        return weighted_metrics(test["y"], preds, self.adapter.num_classes)

    def train(self, rounds=None, *, eval_every=0):
        rounds = rounds or self.cfg.rounds
        cfg = self.cfg
        for _ in range(rounds):
            log = self.run_round()
            if eval_every and (log.round + 1) % eval_every == 0:
                log.metrics = self.evaluate()
            # checkpoint cadence keyed to the GLOBAL round index, not this
            # call's loop counter, so train(50) and train(25)+train(25)
            # write identical checkpoints
            if cfg.checkpoint_dir and cfg.checkpoint_every \
                    and self.global_version % cfg.checkpoint_every == 0:
                self.save_checkpoint(wait=False)
        # final checkpoint at the last round, unless the cadence just
        # wrote one — a resumed run continues from exactly where this
        # train() call stopped, not the last multiple of checkpoint_every
        if cfg.checkpoint_dir and cfg.checkpoint_every \
                and self.global_version % cfg.checkpoint_every != 0:
            self.save_checkpoint(wait=False)
        self._ckpt_drain()
        final = self.evaluate()
        art = float(np.mean([l.art for l in self.logs]))
        return {"metrics": final, "art": art, "aco": self.comm.aco,
                "rounds": len(self.logs),
                "fleet": fleet_health(self.logs)}
