"""Group-based aggregation support (§IV-D3): k-means over client pseudo-label
class distributions.

The server cannot see true client label distributions (clients are
unlabeled!), so clients report the class histogram of their own pseudo-labels
— a privacy-equivalent statistic of what they actually trained on (DESIGN.md
§3). Two implementations share the algorithm:

* ``kmeans`` — float64 numpy on the host (the reference; the sequential and
  batched engines use it, which costs those engines one device->host
  histogram transfer per round).
* ``kmeans_device`` — the same greedy farthest-point init + fixed-iteration
  Lloyd loop as pure jnp under jit (static k/iters, float32). The sharded
  fleet engine runs it on device so the round has zero host syncs. On
  well-separated histograms the assignments are identical to the host path;
  points near-equidistant between centers may tie-break differently under
  float32 vs float64 (the parity test documents the relaxed tolerance).

Both take the first center's index explicitly derivable from ``seed`` via
``init_index`` so they walk the same deterministic init sequence.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def init_index(num_points: int, seed: int = 0) -> int:
    """First k-means center: the reference path's rng.integers draw."""
    return int(np.random.default_rng(seed).integers(num_points))


def kmeans(points, k, *, iters=20, seed=0):
    """points: (M, D) -> (assignments (M,), centers (k, D)). Deterministic
    k-means++-ish init (greedy farthest point)."""
    points = np.asarray(points, dtype=np.float64)
    M = points.shape[0]
    k = min(k, M)
    centers = [points[init_index(M, seed)]]
    for _ in range(1, k):
        d2 = np.min(
            [np.sum((points - c) ** 2, axis=1) for c in centers], axis=0)
        centers.append(points[int(np.argmax(d2))])
    centers = np.stack(centers)
    for _ in range(iters):
        d2 = ((points[:, None] - centers[None]) ** 2).sum(-1)
        assign = d2.argmin(1)
        for j in range(k):
            sel = points[assign == j]
            if len(sel):
                centers[j] = sel.mean(0)
    return assign, centers


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_device(points, k, *, init_idx=0, iters=20):
    """On-device twin of ``kmeans``: points (M, D) -> (assign (M,) int32,
    centers (k, D) float32), fully jitted with static shapes.

    ``init_idx`` is a (possibly traced) scalar — pass ``init_index(M, seed)``
    to reproduce the host init. Greedy farthest-point init unrolls over the
    static k; the Lloyd loop runs exactly ``iters`` times (no convergence
    host check), with empty clusters keeping their previous center — both
    matching the numpy reference.
    """
    points = jnp.asarray(points, jnp.float32)
    M = points.shape[0]
    assert k <= M, (k, M)
    centers = jnp.zeros((k, points.shape[1]), jnp.float32)
    centers = centers.at[0].set(points[init_idx])
    for j in range(1, k):
        # min distance to the j centers chosen so far (static unroll)
        d2 = jnp.min(((points[:, None] - centers[None, :j]) ** 2).sum(-1),
                     axis=1)
        centers = centers.at[j].set(points[jnp.argmax(d2)])

    def lloyd(centers, _):
        d2 = ((points[:, None] - centers[None]) ** 2).sum(-1)
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)    # (M, k)
        cnt = onehot.sum(0)                                      # (k,)
        sums = onehot.T @ points                                 # (k, D)
        new = jnp.where(cnt[:, None] > 0, sums /
                        jnp.maximum(cnt[:, None], 1.0), centers)
        return new, assign

    # like the numpy path, the returned assignment is the one computed
    # inside the final Lloyd iteration (against its pre-update centers)
    centers, assigns = jax.lax.scan(lloyd, centers, None, length=iters)
    return assigns[-1].astype(jnp.int32), centers


def group_clients(histograms, num_groups, *, seed=0):
    """histograms: (M, C) pseudo-label distributions -> group index per client."""
    assign, _ = kmeans(histograms, num_groups, seed=seed)
    return assign


def group_clients_device(histograms, num_groups, *, seed=0):
    """Device-resident ``group_clients``: returns a (M,) int32 jax array with
    no host transfer (the sharded engine's grouping path)."""
    M = histograms.shape[0]
    k = min(num_groups, M)
    assign, _ = kmeans_device(histograms, k, init_idx=init_index(M, seed))
    return assign
