"""Group-based aggregation support (§IV-D3): k-means over client pseudo-label
class distributions.

The server cannot see true client label distributions (clients are
unlabeled!), so clients report the class histogram of their own pseudo-labels
— a privacy-equivalent statistic of what they actually trained on (DESIGN.md
§3). k-means runs with fixed iteration count under jit (static shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def kmeans(points, k, *, iters=20, seed=0):
    """points: (M, D) -> (assignments (M,), centers (k, D)). Deterministic
    k-means++-ish init (greedy farthest point)."""
    points = np.asarray(points, dtype=np.float64)
    M = points.shape[0]
    k = min(k, M)
    rng = np.random.default_rng(seed)
    centers = [points[rng.integers(M)]]
    for _ in range(1, k):
        d2 = np.min(
            [np.sum((points - c) ** 2, axis=1) for c in centers], axis=0)
        centers.append(points[int(np.argmax(d2))])
    centers = np.stack(centers)
    for _ in range(iters):
        d2 = ((points[:, None] - centers[None]) ** 2).sum(-1)
        assign = d2.argmin(1)
        for j in range(k):
            sel = points[assign == j]
            if len(sel):
                centers[j] = sel.mean(0)
    return assign, centers


def group_clients(histograms, num_groups, *, seed=0):
    """histograms: (M, C) pseudo-label distributions -> group index per client."""
    assign, _ = kmeans(histograms, num_groups, seed=seed)
    return assign
