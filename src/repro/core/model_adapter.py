"""Model adapters: one contract between the federated engines and a model.

The trainer's engines only ever touch a model through a small closure set —
init / tree epochs (sequential reference), flat stacked epochs (batched,
sharded and chunked engines), prediction and pseudo-label histograms. This
module packages that set:

* :class:`CNNAdapter` — the paper's CNN, delegating to the SAME lru-cached
  factories in ``core.pseudo_label`` the trainer used to call directly, so
  every flat-path behaviour is bit-identical to the pre-adapter wiring.
* :class:`LMAdapter` — a real language model from the config zoo
  (``configs/base.ModelConfig`` / ``models/lm.py``) federated as a
  final-token classifier over its vocabulary: clients run pseudo-label
  epochs on the last-position logits (Eq. 5 with ``num_classes =
  vocab_size``), the server trains supervised on labeled final tokens
  (Eq. 6). Token sequences ride the engines' existing float32 data plumbing
  as (B, S) rows (exact for any vocab < 2**24) and cast to int32 at the
  loss. The LM forward has no dropout, but the per-batch RNG split is kept
  so the optimizer-step and key-stream structure mirrors the CNN epochs.

Both adapters expose: ``num_classes``, ``param_count()``, ``init(rng)``,
``template``, ``client_epoch``, ``server_epoch``, ``server_epoch_flat``,
``batched_epoch``, ``histogram``, ``histogram_batch``, ``predict``.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pseudo_label import (_cnn_template, class_histogram,
                                     class_histogram_batch,
                                     make_batched_client_epoch,
                                     make_client_epoch, make_server_epoch,
                                     make_server_epoch_flat, predict_fn)
from repro.kernels import ops as kops
from repro.kernels.ref import masked_pseudo_ce_ref
from repro.models.cnn import cnn_param_count, init_cnn
from repro.optimizer import adam_update

__all__ = ["CNNAdapter", "LMAdapter", "make_adapter"]


def make_adapter(cfg, *, batch_size, threshold, l1, use_kernel, epochs):
    """CNNConfig -> CNNAdapter, ModelConfig (LM zoo) -> LMAdapter."""
    if isinstance(cfg, ModelConfig):
        return LMAdapter(cfg, batch_size=batch_size, threshold=threshold,
                         l1=l1, use_kernel=use_kernel, epochs=epochs)
    return CNNAdapter(cfg, batch_size=batch_size, threshold=threshold,
                      l1=l1, use_kernel=use_kernel, epochs=epochs)


class CNNAdapter:
    """The paper's CNN behind the adapter contract. Pure delegation to the
    lru-cached ``core.pseudo_label`` factories with identical arguments, so
    trainers sharing a config share compiled steps exactly as before."""

    kind = "cnn"

    def __init__(self, cfg, *, batch_size, threshold, l1, use_kernel,
                 epochs):
        self.cfg = cfg
        self.num_classes = cfg.num_classes
        self.client_epoch = make_client_epoch(
            cfg, batch_size=batch_size, threshold=threshold, l1=l1,
            use_kernel=use_kernel)
        self.server_epoch = make_server_epoch(cfg, batch_size=batch_size,
                                              l1=l1)
        self.server_epoch_flat = make_server_epoch_flat(
            cfg, batch_size=batch_size, l1=l1)
        self.batched_epoch = make_batched_client_epoch(
            cfg, batch_size=batch_size, threshold=threshold, l1=l1,
            use_kernel=use_kernel, epochs=epochs)
        self.predict = predict_fn(cfg)
        self.histogram = class_histogram(cfg)
        self.histogram_batch = class_histogram_batch(cfg,
                                                     batch_size=batch_size)

    def param_count(self):
        return cnn_param_count(self.cfg)

    def init(self, rng):
        return init_cnn(self.cfg, rng)

    @property
    def template(self):
        return _cnn_template(self.cfg)


# ---------------------------------------------------------------------------
# LM-as-classifier closures (structure mirrors core.pseudo_label factories)

@functools.lru_cache(maxsize=None)
def _lm_template(cfg):
    from repro.models.lm import init_params
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def _lm_logits(cfg, params, x):
    """Last-position logits (B, V) of float-carried token rows (B, S)."""
    from repro.models.lm import forward
    tokens = x.astype(jnp.int32)
    logits, _, _ = forward(cfg, params, {"tokens": tokens},
                           head_mode="last")
    return logits


def _lm_pseudo_loss(cfg, params, xi, vi, *, threshold, use_kernel):
    """Eq. 5 on the final-token logits, masked over padded samples."""
    logits = _lm_logits(cfg, params, xi)
    if use_kernel:
        loss, _ = kops.masked_pseudo_ce(logits, threshold)
    else:
        loss, _ = masked_pseudo_ce_ref(logits, threshold)
    return jnp.sum(loss * vi) / jnp.maximum(jnp.sum(vi), 1.0)


def _lm_sup_loss(cfg, params, xi, yi, vi):
    """Eq. 6: supervised CE of the final-token logits vs the label."""
    logits = _lm_logits(cfg, params, xi)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, yi[:, None], axis=-1)[:, 0]
    return jnp.sum(ce * vi) / jnp.maximum(jnp.sum(vi), 1.0)


def _pad_batches(x_np, batch_size, y_np=None):
    n = len(x_np)
    nb = max((n + batch_size - 1) // batch_size, 1)
    pad = nb * batch_size - n
    x = np.concatenate([x_np, np.zeros((pad,) + x_np.shape[1:],
                                       x_np.dtype)]) if pad else x_np
    valid = np.concatenate([np.ones(n, np.float32),
                            np.zeros(pad, np.float32)])
    if y_np is None:
        return x, valid, nb
    y = np.concatenate([y_np, np.zeros(pad, y_np.dtype)]) if pad else y_np
    return x, y, valid, nb


@functools.lru_cache(maxsize=None)
def _lm_suite(cfg, batch_size, threshold, l1, use_kernel, epochs):
    """All LM closures for one (config, hyperparams) point, built once.
    Each mirrors its ``core.pseudo_label`` namesake: padded batches with a
    validity mask, scan over batches, cond-skipped all-padding batches in
    the stacked epochs, flat Adam state, and the epoch-index key fold for
    epochs > 0."""
    from repro.core.sparse_comm import unflatten_like, unflatten_stacked

    template = _lm_template(cfg)

    @partial(jax.jit, static_argnames=("nb",))
    def tree_client_epoch(params, opt, x, valid, lr, rng, nb):
        xb = x.reshape(nb, batch_size, -1)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            params, opt, rng = carry
            xi, vi = inp
            rng, _ = jax.random.split(rng)
            l, g = jax.value_and_grad(
                lambda p: _lm_pseudo_loss(cfg, p, xi, vi,
                                          threshold=threshold,
                                          use_kernel=use_kernel))(params)
            params, opt = adam_update(g, opt, params, lr=lr, l1=l1)
            return (params, opt, rng), l

        (params, opt, _), losses = jax.lax.scan(step, (params, opt, rng),
                                                (xb, vb))
        return params, opt, jnp.mean(losses)

    def client_epoch(params, opt, x_np, lr, rng):
        x, valid, nb = _pad_batches(np.asarray(x_np, np.float32), batch_size)
        return tree_client_epoch(params, opt, jnp.asarray(x),
                                 jnp.asarray(valid), jnp.float32(lr), rng, nb)

    @partial(jax.jit, static_argnames=("nb", "flat_state"))
    def server_step(state, opt, x, y, valid, lr, rng, nb, flat_state):
        xb = x.reshape(nb, batch_size, -1)
        yb = y.reshape(nb, batch_size)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            state, opt, rng = carry
            xi, yi, vi = inp
            rng, _ = jax.random.split(rng)

            def loss_fn(s):
                p = unflatten_like(s, template) if flat_state else s
                return _lm_sup_loss(cfg, p, xi, yi, vi)

            l, g = jax.value_and_grad(loss_fn)(state)
            state, opt = adam_update(g, opt, state, lr=lr, l1=l1)
            return (state, opt, rng), l

        (state, opt, _), losses = jax.lax.scan(step, (state, opt, rng),
                                               (xb, yb, vb))
        return state, opt, jnp.mean(losses)

    def _server_run(state, opt, x_np, y_np, lr, rng, flat_state):
        x, y, valid, nb = _pad_batches(np.asarray(x_np, np.float32),
                                       batch_size,
                                       np.asarray(y_np, np.int32))
        return server_step(state, opt, jnp.asarray(x), jnp.asarray(y),
                           jnp.asarray(valid), jnp.float32(lr), rng, nb,
                           flat_state)

    def server_epoch(params, opt, x_np, y_np, lr, rng):
        return _server_run(params, opt, x_np, y_np, lr, rng, False)

    def server_epoch_flat(flat, opt, x_np, y_np, lr, rng):
        return _server_run(flat, opt, x_np, y_np, lr, rng, True)

    @partial(jax.jit, static_argnames=("nb",))
    def stacked_epoch(base_flat, x, valid, lrs, rngs, nb):
        def one_client(flat, xc, vc, lr, rng):
            xb = xc.reshape(nb, batch_size, -1)
            vb = vc.reshape(nb, batch_size)
            opt = {"m": jnp.zeros_like(flat), "v": jnp.zeros_like(flat),
                   "t": jnp.zeros((), jnp.int32)}

            def step(carry, inp):
                flat, o, rng = carry
                xi, vi = inp
                rng, _ = jax.random.split(rng)

                def live_step(_):
                    def loss_fn(fp):
                        pp = unflatten_like(fp, template)
                        return _lm_pseudo_loss(cfg, pp, xi, vi,
                                               threshold=threshold,
                                               use_kernel=use_kernel)
                    l, g = jax.value_and_grad(loss_fn)(flat)
                    f2, o2 = adam_update(g, o, flat, lr=lr, l1=l1)
                    return f2, o2, l

                def dead_step(_):
                    return flat, o, jnp.float32(0.0)

                live = jnp.sum(vi) > 0
                flat, o, l = jax.lax.cond(live, live_step, dead_step, None)
                return (flat, o, rng), (l, live)

            for e in range(epochs):
                ek = rng if e == 0 else jax.random.fold_in(rng, e)
                (flat, opt, _), (losses, lives) = jax.lax.scan(
                    step, (flat, opt, ek), (xb, vb))
            return flat, jnp.sum(losses) / jnp.maximum(jnp.sum(lives), 1.0)

        if jax.default_backend() == "cpu":
            def all_clients(*args):
                return jax.lax.map(lambda t: one_client(*t), args)
        else:
            def all_clients(*args):
                return jax.vmap(one_client)(*args)

        return all_clients(base_flat, x, valid, lrs, rngs)

    def batched_epoch(base_flat, x, valid, lrs, rngs):
        nb = x.shape[1] // batch_size
        return stacked_epoch(base_flat, x, valid,
                             jnp.asarray(lrs, jnp.float32), rngs, nb)

    @jax.jit
    def predict(params, x):
        return jnp.argmax(_lm_logits(cfg, params, x), axis=-1)

    @jax.jit
    def histogram(params, x):
        pred = jnp.argmax(_lm_logits(cfg, params, x), axis=-1)
        return jnp.bincount(pred, length=cfg.vocab_size) / x.shape[0]

    def hist_one(p, x, valid):
        xb = x.reshape(-1, batch_size, x.shape[-1])
        vb = valid.reshape(-1, batch_size)

        def step(acc, inp):
            xi, vi = inp
            counts = jax.lax.cond(
                jnp.sum(vi) > 0,
                lambda _: jnp.zeros(cfg.vocab_size, jnp.float32)
                .at[jnp.argmax(_lm_logits(cfg, p, xi), axis=-1)].add(vi),
                lambda _: jnp.zeros(cfg.vocab_size, jnp.float32), None)
            return acc + counts, None

        acc, _ = jax.lax.scan(step, jnp.zeros(cfg.vocab_size, jnp.float32),
                              (xb, vb))
        return acc / jnp.maximum(jnp.sum(valid), 1.0)

    if jax.default_backend() == "cpu":
        def hist_mapped(params, x, valid):
            return jax.lax.map(lambda t: hist_one(*t), (params, x, valid))
    else:
        def hist_mapped(params, x, valid):
            return jax.vmap(hist_one)(params, x, valid)

    @jax.jit
    def histogram_batch(flat, x, valid):
        params = unflatten_stacked(flat, template)
        return hist_mapped(params, x, valid)

    return {"client_epoch": client_epoch, "server_epoch": server_epoch,
            "server_epoch_flat": server_epoch_flat,
            "batched_epoch": batched_epoch, "predict": predict,
            "histogram": histogram, "histogram_batch": histogram_batch}


class LMAdapter:
    """A config-zoo LM federated as a final-token classifier (see module
    docstring). ``num_classes`` is the vocabulary size; data rows are
    float-carried token sequences."""

    kind = "lm"

    def __init__(self, cfg, *, batch_size, threshold, l1, use_kernel,
                 epochs):
        self.cfg = cfg
        self.num_classes = cfg.vocab_size
        suite = _lm_suite(cfg, batch_size, threshold, l1, use_kernel,
                          epochs)
        self.client_epoch = suite["client_epoch"]
        self.server_epoch = suite["server_epoch"]
        self.server_epoch_flat = suite["server_epoch_flat"]
        self.batched_epoch = suite["batched_epoch"]
        self.predict = suite["predict"]
        self.histogram = suite["histogram"]
        self.histogram_batch = suite["histogram_batch"]

    def param_count(self):
        return int(self.cfg.param_count())

    def init(self, rng):
        from repro.models.lm import init_params
        return init_params(self.cfg, rng)

    @property
    def template(self):
        return _lm_template(self.cfg)
