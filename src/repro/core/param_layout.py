"""Chunked parameter-axis layout (§IV-F at real-model scale).

Every fleet engine flattens client parameters to one vector of length N
and stacks the round's K participants as ``(K, N)``.  For the paper's CNN
(N ≈ 1e5) materializing per-stage ``(K, N)`` delta buffers is free; for
the real LM configs the repo carries (``configs/qwen2_1_5b.py``,
``configs/xlstm_125m.py``) it is the memory wall.  :class:`ParamLayout`
partitions ``[0, N)`` into contiguous chunks **aligned to parameter-leaf
boundaries** so the sparse-diff encode, the versioned-ring advance, and
the fused server blends stream one chunk at a time — peak device delta
memory is O(K · max_chunk) instead of O(K · N).

Leaf alignment is what makes per-layer sparsity fall out: a chunk never
spans two leaves with different ``keep_frac`` overrides, so the per-row
quantile thresholds the kernels already compute become per-layer
thresholds for free (embedding vs head sparsity differ; FedIoT-style
on-device fleets want aggressive embedding sparsity and conservative
head sparsity).

The degenerate single-chunk layout *is* the historical flat path: a
resolved layout with ``num_chunks == 1`` routes through exactly the same
code as no layout at all, which is how the engine parity matrix pins
chunked-off bit-identical to the seed behaviour.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np

__all__ = ["ParamLayout", "leaf_sizes"]


def _path_name(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:  # pragma: no cover - future pytree key kinds
            parts.append(str(p))
    return "/".join(parts)


def leaf_sizes(template):
    """``[(name, size), ...]`` for a pytree of arrays/ShapeDtypeStructs, in
    the same traversal order ``flatten_tree`` uses to build the flat vector."""
    leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    return [(_path_name(path), int(np.prod(leaf.shape)) if leaf.shape else 1)
            for path, leaf in leaves]


def _match_override(name, overrides):
    """First override whose pattern is a substring of the leaf name.

    Values may be a float (keep_frac), a ``(keep_frac, residual_frac)``
    pair, or a dict with ``keep_frac`` / ``residual_frac`` keys.
    """
    if not overrides:
        return (None, None)
    for pat, val in overrides.items():
        if pat in name:
            if isinstance(val, dict):
                return (val.get("keep_frac"), val.get("residual_frac"))
            if isinstance(val, (tuple, list)):
                return (val[0], val[1] if len(val) > 1 else None)
            return (float(val), None)
    return (None, None)


@dataclass(frozen=True)
class ParamLayout:
    """Immutable partition of the flat parameter axis ``[0, n)``.

    ``bounds`` are contiguous ``(start, end)`` half-open chunk spans that
    cover ``[0, n)`` exactly.  ``keep_frac`` / ``residual_frac`` hold one
    entry per chunk; ``None`` means "use the channel default" so a layout
    without overrides accounts bytes identically to the flat path.
    """

    n: int
    bounds: tuple
    keep_frac: tuple = ()
    residual_frac: tuple = ()
    names: tuple = field(default=(), compare=False)

    def __post_init__(self):
        if not self.bounds:
            raise ValueError("ParamLayout needs at least one chunk")
        pos = 0
        for s, e in self.bounds:
            if s != pos or e <= s:
                raise ValueError(
                    f"chunk bounds must be contiguous and non-empty; got "
                    f"({s}, {e}) at offset {pos}")
            pos = e
        if pos != self.n:
            raise ValueError(f"chunks cover [0, {pos}) but n={self.n}")
        c = len(self.bounds)
        if not self.keep_frac:
            object.__setattr__(self, "keep_frac", (None,) * c)
        if not self.residual_frac:
            object.__setattr__(self, "residual_frac", (None,) * c)
        if len(self.keep_frac) != c or len(self.residual_frac) != c:
            raise ValueError("per-chunk frac tuples must match num_chunks")

    # -- shape facts ------------------------------------------------------
    @property
    def num_chunks(self):
        return len(self.bounds)

    @property
    def sizes(self):
        return tuple(e - s for s, e in self.bounds)

    @property
    def max_chunk(self):
        return max(self.sizes)

    @property
    def is_flat(self):
        """Single chunk with no sparsity overrides == the historical path."""
        return (self.num_chunks == 1
                and self.keep_frac[0] is None
                and self.residual_frac[0] is None)

    # -- constructors -----------------------------------------------------
    @staticmethod
    def flat(n):
        return ParamLayout(n=int(n), bounds=((0, int(n)),))

    @classmethod
    def from_template(cls, template, chunk_size, *, overrides=None):
        """Partition a parameter pytree into leaf-aligned chunks.

        Consecutive leaves sharing the same (possibly absent) sparsity
        override are greedily packed into chunks of at most ``chunk_size``
        parameters; a leaf larger than ``chunk_size`` is split internally
        with a ragged last piece.  Leaves with distinct overrides never
        share a chunk, so per-layer ``keep_frac`` maps exactly onto
        per-chunk thresholds.
        """
        chunk_size = int(chunk_size)
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        bounds, keeps, residuals, names = [], [], [], []
        cur_start, cur_end, cur_ov, cur_names = None, None, None, []

        def close():
            nonlocal cur_start
            if cur_start is not None:
                bounds.append((cur_start, cur_end))
                keeps.append(cur_ov[0])
                residuals.append(cur_ov[1])
                names.append("+".join(cur_names))
                cur_start = None

        offset = 0
        for name, size in leaf_sizes(template):
            ov = _match_override(name, overrides)
            if size > chunk_size:
                close()
                for s in range(offset, offset + size, chunk_size):
                    e = min(s + chunk_size, offset + size)
                    bounds.append((s, e))
                    keeps.append(ov[0])
                    residuals.append(ov[1])
                    names.append(name)
            elif (cur_start is not None and ov == cur_ov
                  and cur_end - cur_start + size <= chunk_size):
                cur_end += size
                cur_names.append(name)
            else:
                close()
                cur_start, cur_end, cur_ov = offset, offset + size, ov
                cur_names = [name]
            offset += size
        close()
        return cls(n=offset, bounds=tuple(bounds), keep_frac=tuple(keeps),
                   residual_frac=tuple(residuals), names=tuple(names))

    # -- reporting --------------------------------------------------------
    def describe(self):
        return {
            "n": self.n,
            "num_chunks": self.num_chunks,
            "max_chunk": self.max_chunk,
            "min_chunk": min(self.sizes),
            "overridden_chunks": sum(
                1 for k, r in zip(self.keep_frac, self.residual_frac)
                if k is not None or r is not None),
        }

    def __repr__(self):  # keep log lines short at hundreds of chunks
        return (f"ParamLayout(n={self.n}, num_chunks={self.num_chunks}, "
                f"max_chunk={self.max_chunk})")
