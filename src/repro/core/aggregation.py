"""FedS3A aggregation functions (§IV-D, Eq. 7-10).

All variants take the participating clients' parameters, data sizes,
stalenesses and the server's supervised parameters, and return the new global
model. The group-based variant (Eq. 10) averages |D|-weighted + g(s)-decayed
within each k-means group and arithmetically across groups; the flat variant
(Eq. 9) skips grouping; Eq. 7/8 ablations are expressible via flags.

The heavy weighted sum runs through the Pallas staleness_agg kernel when
``use_kernel`` (one VMEM pass over the stacked client deltas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_comm import flatten_tree, unflatten_like
from repro.kernels import ops as kops


def _weighted_sum_trees(trees, weights, *, use_kernel=False):
    weights = jnp.asarray(weights, jnp.float32)
    if use_kernel:
        stack = jnp.stack([flatten_tree(t) for t in trees])
        flat = kops.staleness_agg(stack, weights)
        return unflatten_like(flat, trees[0])
    out = jax.tree.map(lambda *ls: sum(w * l.astype(jnp.float32)
                                       for w, l in zip(weights, ls)), *trees)
    return jax.tree.map(lambda a, b: a.astype(b.dtype), out, trees[0])


def combine_weights(data_sizes, stalenesses, g_fn, groups=None):
    """Fold Eq. 9/10 into ONE per-client weight vector.

    Flat (Eq. 9): w_i ∝ |D_i| * g(s_i), normalized as in ``aggregate``.
    Grouped (Eq. 10): w_i = (1/G) * |D_i| g(s_i) / sum_{j in group(i)} |D_j|
    g(s_j) — the within-group weighted mean followed by the arithmetic mean
    across groups collapses to a single weighted sum over clients, which is
    what lets the batched engine aggregate the whole (K, N) delta stack in
    one kernel pass.
    """
    data_sizes = np.asarray(data_sizes, dtype=np.float64)
    g = np.array([g_fn(s) for s in stalenesses], dtype=np.float64)
    if groups is None:
        w = data_sizes * g
        w = w / max(data_sizes.sum(), 1e-12)
        return w / max(w.sum(), 1e-12)
    groups = np.asarray(groups)
    uniq = np.unique(groups)
    w = np.zeros(len(data_sizes))
    for gidx in uniq:
        sel = groups == gidx
        wg = data_sizes[sel] * g[sel]
        w[sel] = wg / max(wg.sum(), 1e-12) / len(uniq)
    return w


@jax.jit
def _blend_flat(server_flat, client_flat, w, f_weight):
    unsup = jnp.einsum("k,kn->n", w, client_flat.astype(jnp.float32))
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


@jax.jit
def _blend_flat_kernel(server_flat, client_flat, w, f_weight):
    unsup = kops.staleness_agg(client_flat, w)
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def aggregate_flat(server_flat, client_flat, *, data_sizes, stalenesses,
                   g_fn, f_weight, groups=None, use_kernel=False):
    """FedS3A global update on already-flattened stacks (the batched engine).

    server_flat: (N,) supervised model; client_flat: (K, N) stacked uploaded
    client models. Returns the new global model as an (N,) fp32 flat vector —
    one jitted weighted-sum pass (Pallas staleness_agg when ``use_kernel``)
    plus the f(r) blend, with no per-tree flatten/stack.
    """
    w = combine_weights(data_sizes, stalenesses, g_fn, groups)
    blend = _blend_flat_kernel if use_kernel else _blend_flat
    return blend(server_flat, client_flat, jnp.asarray(w, jnp.float32),
                 jnp.float32(f_weight))


def aggregate(server_params, client_params, *, data_sizes, stalenesses,
              g_fn, f_weight, groups=None, use_kernel=False):
    """FedS3A global update.

    server_params: supervised model omega_s^{r+1}
    client_params: list of participating clients' models omega_i^{r_i+1}
    data_sizes:    |D_i| per participant
    stalenesses:   r - r_i per participant
    g_fn:          staleness function
    f_weight:      f(r), the dynamic supervised weight
    groups:        optional group index per participant (Eq. 10); None -> Eq. 9
    """
    data_sizes = np.asarray(data_sizes, dtype=np.float64)
    g = np.array([g_fn(s) for s in stalenesses], dtype=np.float64)

    if groups is None:
        w = data_sizes * g
        w = w / max(data_sizes.sum(), 1e-12)
        # Eq. 9: weights |D_i|/|D_c| * g(s_i) (not renormalized; g shrinks
        # stale contributions relative to the fresh ones)
        w = w / max(w.sum(), 1e-12)
        unsup = _weighted_sum_trees(client_params, w, use_kernel=use_kernel)
    else:
        groups = np.asarray(groups)
        uniq = np.unique(groups)
        group_models = []
        for gidx in uniq:
            sel = np.where(groups == gidx)[0]
            dg = data_sizes[sel]
            wg = dg * g[sel]
            wg = wg / max(wg.sum(), 1e-12)
            group_models.append(_weighted_sum_trees(
                [client_params[i] for i in sel], wg, use_kernel=use_kernel))
        w = np.full(len(group_models), 1.0 / len(group_models))
        unsup = _weighted_sum_trees(group_models, w, use_kernel=use_kernel)

    return jax.tree.map(
        lambda s, u: (f_weight * s.astype(jnp.float32) +
                      (1.0 - f_weight) * u.astype(jnp.float32)).astype(s.dtype),
        server_params, unsup)


def fedavg(client_params, data_sizes):
    """Eq. 3 (plain FedAvg over clients)."""
    w = np.asarray(data_sizes, dtype=np.float64)
    w = w / w.sum()
    return _weighted_sum_trees(client_params, w)


def fedavg_ssl(server_params, client_params, data_sizes, f_weight):
    """Eq. 8: FedAvg + dynamic supervised weight (the adapted baseline)."""
    unsup = fedavg(client_params, data_sizes)
    return jax.tree.map(
        lambda s, u: (f_weight * s.astype(jnp.float32) +
                      (1.0 - f_weight) * u.astype(jnp.float32)).astype(s.dtype),
        server_params, unsup)


def fedasync_blend(global_params, client_params, *, staleness, alpha=0.9,
                   a=0.5):
    """FedAsync [Xie et al. 2019] mixing with polynomial staleness decay
    (alpha=0.9, a=0.5 — the best-performing combination per the paper; the
    proximal rho=0.005 term lives in the client loss, handled by L2 in the
    baseline trainer)."""
    alpha_t = min(alpha * (staleness + 1.0) ** (-a), 1.0)
    return jax.tree.map(
        lambda gp, cp: ((1 - alpha_t) * gp.astype(jnp.float32) +
                        alpha_t * cp.astype(jnp.float32)).astype(gp.dtype),
        global_params, client_params)
