"""FedS3A aggregation functions (§IV-D, Eq. 7-10).

All variants take the participating clients' parameters, data sizes,
stalenesses and the server's supervised parameters, and return the new global
model. The group-based variant (Eq. 10) averages |D|-weighted + g(s)-decayed
within each k-means group and arithmetically across groups; the flat variant
(Eq. 9) skips grouping; Eq. 7/8 ablations are expressible via flags.

The heavy weighted sum runs through the Pallas staleness_agg kernel when
``use_kernel`` (one VMEM pass over the stacked client deltas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_comm import flatten_tree, unflatten_like
from repro.kernels import ops as kops


def _weighted_sum_trees(trees, weights, *, use_kernel=False):
    weights = jnp.asarray(weights, jnp.float32)
    if use_kernel:
        stack = jnp.stack([flatten_tree(t) for t in trees])
        flat = kops.staleness_agg(stack, weights)
        return unflatten_like(flat, trees[0])
    out = jax.tree.map(lambda *ls: sum(w * l.astype(jnp.float32)
                                       for w, l in zip(weights, ls)), *trees)
    return jax.tree.map(lambda a, b: a.astype(b.dtype), out, trees[0])


def combine_weights(data_sizes, stalenesses, g_fn, groups=None):
    """Fold Eq. 9/10 into ONE per-client weight vector.

    Flat (Eq. 9): w_i ∝ |D_i| * g(s_i), normalized as in ``aggregate``.
    Grouped (Eq. 10): w_i = (1/G) * |D_i| g(s_i) / sum_{j in group(i)} |D_j|
    g(s_j) — the within-group weighted mean followed by the arithmetic mean
    across groups collapses to a single weighted sum over clients, which is
    what lets the batched engine aggregate the whole (K, N) delta stack in
    one kernel pass.

    Cold start: a participant set (or a whole group) whose combined
    |D|*g(s) mass is zero — empty shards after dataset scaling, or g(s)
    driven to 0 by extreme staleness — used to normalize to an all-zero
    weight vector, which silently dropped those clients from the aggregate
    and re-broadcast the supervised model scaled by f(r) alone (the global
    model shrank toward the server model with no signal that anything was
    wrong). Zero-mass sets now fall back to an explicit uniform weight so
    every participant the scheduler admitted contributes.
    """
    data_sizes = np.asarray(data_sizes, dtype=np.float64)
    g = np.array([g_fn(s) for s in stalenesses], dtype=np.float64)
    if groups is None:
        w = data_sizes * g
        if w.sum() <= 0.0:
            return np.full(len(w), 1.0 / max(len(w), 1))
        w = w / max(data_sizes.sum(), 1e-12)
        return w / max(w.sum(), 1e-12)
    groups = np.asarray(groups)
    uniq = np.unique(groups)
    w = np.zeros(len(data_sizes))
    for gidx in uniq:
        sel = groups == gidx
        wg = data_sizes[sel] * g[sel]
        if wg.sum() <= 0.0:
            w[sel] = 1.0 / (sel.sum() * len(uniq))
        else:
            w[sel] = wg / wg.sum() / len(uniq)
    return w


def combine_weights_device(size_g, groups, num_groups):
    """On-device twin of ``combine_weights`` for the sharded fleet engine.

    size_g: (K,) jnp — |D_i| * g(s_i) per participant (host-computable from
    the scheduler, so it arrives as data); groups: (K,) int32 device array
    (from ``grouping.kmeans_device``); num_groups: static int >= the number
    of distinct labels. Returns the (K,) fp32 weight vector with the same
    grouped normalization and uniform cold-start fallback as the host path,
    computed entirely under jit — group count G counts non-empty groups only,
    matching np.unique on the host.
    """
    size_g = jnp.asarray(size_g, jnp.float32)
    K = size_g.shape[0]
    onehot = jax.nn.one_hot(groups, num_groups, dtype=jnp.float32)  # (K, G)
    cnt = onehot.sum(0)                                             # (G,)
    mass = onehot.T @ size_g                                        # (G,)
    G = jnp.maximum(jnp.sum(cnt > 0), 1).astype(jnp.float32)
    per_group = jnp.where(
        mass > 0,
        size_g[:, None] * onehot / jnp.maximum(mass, 1e-30),
        onehot / jnp.maximum(cnt, 1.0))                             # (K, G)
    return per_group.sum(1) / G


def combine_weights_flat_device(size_g):
    """Flat (Eq. 9) device weights: normalize with uniform cold-start."""
    size_g = jnp.asarray(size_g, jnp.float32)
    total = jnp.sum(size_g)
    K = size_g.shape[0]
    return jnp.where(total > 0, size_g / jnp.maximum(total, 1e-30),
                     jnp.full((K,), 1.0 / K, jnp.float32))


@jax.jit
def _blend_flat(server_flat, client_flat, w, f_weight):
    unsup = jnp.einsum("k,kn->n", w, client_flat.astype(jnp.float32))
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


@jax.jit
def _blend_flat_kernel(server_flat, client_flat, w, f_weight):
    unsup = kops.staleness_agg(client_flat, w)
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def csr_weighted_scatter(values, indices, w, n):
    """Fused server-side decode + weighted sum of K CSR payload rows.

    values/indices: (K, cap) compacted payloads (padding slots carry value 0
    at index 0, so they scatter nothing); w: (K,) combined Eq. 9/10 weights.
    Returns sum_k w_k * decode(payload_k) as an (n,) fp32 vector via ONE
    flat scatter-add of K*cap contributions — the dense (K, n) decode is
    never materialized, which is what makes the compacted upload cheaper to
    aggregate than the masked-dense stack it replaces.
    """
    contrib = w[:, None].astype(jnp.float32) * values.astype(jnp.float32)
    return jnp.zeros((n,), jnp.float32).at[indices.reshape(-1)].add(
        contrib.reshape(-1))


def blend_flat_csr(server_flat, base_flat, values, indices, w, f_weight,
                   *, use_kernel=False):
    """FedS3A global update from CSR upload payloads (the compacted wire
    format): uploaded_k = base_k + decode(payload_k), so the weighted client
    sum splits into the dense base sum (Pallas ``staleness_agg`` when
    ``use_kernel``) plus one fused weighted scatter-add of the payloads.
    """
    w = w.astype(jnp.float32)
    if use_kernel:
        base_sum = kops.staleness_agg(base_flat, w)
    else:
        base_sum = jnp.einsum("k,kn->n", w, base_flat.astype(jnp.float32))
    unsup = base_sum + csr_weighted_scatter(values, indices, w,
                                            server_flat.shape[0])
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def blend_flat_sharded_csr(server_flat, base_local, values_local,
                           indices_local, w_local, f_weight, *, axis_name,
                           use_kernel=False):
    """``blend_flat_csr`` inside a ``shard_map`` over the client axis: each
    shard folds its local base rows and payload rows (pad rows carry weight
    0 and value-0/index-0 payload slots, so they vanish), and one psum
    produces the replicated weighted client sum before the f(r) blend."""
    w_local = w_local.astype(jnp.float32)
    if use_kernel:
        base_sum = kops.staleness_agg(base_local, w_local)
    else:
        base_sum = jnp.einsum("k,kn->n", w_local,
                              base_local.astype(jnp.float32))
    partial = base_sum + csr_weighted_scatter(values_local, indices_local,
                                              w_local, server_flat.shape[0])
    unsup = jax.lax.psum(partial, axis_name)
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def csr_q_weighted_scatter(qvals, qoffs, qcnt, scales, w, n):
    """Fused server-side decode of K quantized csr_q payload rows into the
    weighted client sum — the csr_q twin of :func:`csr_weighted_scatter`.

    qvals: (K, cap) int8 (or f16) quantized values; qoffs: (K, cap) int16
    in-block column offsets; qcnt: (K, nblk) int16 per-block counts (the
    index decoder's side information); scales: (K,) f32 per-row absmax
    scales (all-ones for fp16 payloads); w: (K,) combined Eq. 9/10 weights.

    Absolute columns are reconstructed exactly as a receiver would —
    block id per slot via a vmapped binary search over the cumulative
    block counts (ref.csr_unpack_indices_ref inlined so the whole decode
    jits into the blend), then ``block * 512 + offset`` — and
    dequantization FUSES into the weight multiply: the contribution of row
    k is ``(w_k * scale_k) * qvals_k``, so the f32 payload is never
    materialized. Padding slots carry value 0 at a clamped index and
    scatter nothing. Returns sum_k w_k * dequant(decode(payload_k)) as an
    (n,) fp32 vector via one flat scatter-add.
    """
    K, cap = qoffs.shape
    nblk = qcnt.shape[1]
    cum = jnp.cumsum(qcnt.astype(jnp.int32), axis=1)
    slots = jnp.arange(cap, dtype=jnp.int32)
    blk = jax.vmap(lambda c: jnp.searchsorted(c, slots, side="right"))(cum)
    idx = jnp.minimum(blk, nblk - 1).astype(jnp.int32) * 512 + \
        qoffs.astype(jnp.int32)
    idx = jnp.minimum(idx, n - 1)
    contrib = (w.astype(jnp.float32) *
               scales.astype(jnp.float32))[:, None] * \
        qvals.astype(jnp.float32)
    return jnp.zeros((n,), jnp.float32).at[idx.reshape(-1)].add(
        contrib.reshape(-1))


def blend_flat_csr_q(server_flat, base_flat, qvals, qoffs, qcnt, scales, w,
                     f_weight, *, use_kernel=False):
    """FedS3A global update from quantized csr_q upload payloads:
    uploaded_k = base_k + dequant(decode(payload_k)), so the weighted
    client sum splits into the dense base sum plus one fused
    dequantizing weighted scatter-add of the quantized payloads."""
    w = w.astype(jnp.float32)
    if use_kernel:
        base_sum = kops.staleness_agg(base_flat, w)
    else:
        base_sum = jnp.einsum("k,kn->n", w, base_flat.astype(jnp.float32))
    unsup = base_sum + csr_q_weighted_scatter(qvals, qoffs, qcnt, scales, w,
                                              server_flat.shape[0])
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def blend_flat_sharded_csr_q(server_flat, base_local, qvals_local,
                             qoffs_local, qcnt_local, scales_local, w_local,
                             f_weight, *, axis_name, use_kernel=False):
    """``blend_flat_csr_q`` inside a ``shard_map`` over the client axis:
    each shard folds its local base rows and quantized payload rows (pad
    rows carry weight 0 and zero-valued payload slots, so they vanish),
    and one psum produces the replicated weighted client sum."""
    w_local = w_local.astype(jnp.float32)
    if use_kernel:
        base_sum = kops.staleness_agg(base_local, w_local)
    else:
        base_sum = jnp.einsum("k,kn->n", w_local,
                              base_local.astype(jnp.float32))
    partial = base_sum + csr_q_weighted_scatter(
        qvals_local, qoffs_local, qcnt_local, scales_local, w_local,
        server_flat.shape[0])
    unsup = jax.lax.psum(partial, axis_name)
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def aggregate_flat_csr(server_flat, base_flat, values, indices, *,
                       data_sizes, stalenesses, g_fn, f_weight, groups=None,
                       use_kernel=False):
    """FedS3A global update on compacted uploads: ``combine_weights`` folds
    Eq. 9/10 into one weight vector, then ``blend_flat_csr`` consumes the
    CSR payloads directly (scatter-add decode fused into the aggregation).
    """
    w = combine_weights(data_sizes, stalenesses, g_fn, groups)
    return blend_flat_csr(server_flat, base_flat, values, indices,
                          jnp.asarray(w, jnp.float32), jnp.float32(f_weight),
                          use_kernel=use_kernel)


def blend_flat_sharded(server_flat, client_flat_local, w_local, f_weight,
                       *, axis_name, use_kernel=False):
    """FedS3A global update inside a ``shard_map`` over the client axis.

    Each shard holds a (K_local, N) slice of the uploaded client stack and
    the matching (K_local,) slice of the combined Eq. 9/10 weights (pad rows
    carry weight 0, so they vanish from the sum). The weighted reduction
    runs locally — one ``staleness_agg`` kernel pass per shard when
    ``use_kernel`` — and a single psum over ``axis_name`` produces the
    replicated global weighted sum; every device then applies the f(r)
    blend to its own copy. One collective per round, O(N) bytes.
    """
    if use_kernel:
        partial_sum = kops.staleness_agg(client_flat_local, w_local)
    else:
        partial_sum = jnp.einsum("k,kn->n", w_local.astype(jnp.float32),
                                 client_flat_local.astype(jnp.float32))
    unsup = jax.lax.psum(partial_sum, axis_name)
    return f_weight * server_flat.astype(jnp.float32) + \
        (1.0 - f_weight) * unsup


def aggregate_flat(server_flat, client_flat, *, data_sizes, stalenesses,
                   g_fn, f_weight, groups=None, use_kernel=False):
    """FedS3A global update on already-flattened stacks (the batched engine).

    server_flat: (N,) supervised model; client_flat: (K, N) stacked uploaded
    client models. Returns the new global model as an (N,) fp32 flat vector —
    one jitted weighted-sum pass (Pallas staleness_agg when ``use_kernel``)
    plus the f(r) blend, with no per-tree flatten/stack.
    """
    w = combine_weights(data_sizes, stalenesses, g_fn, groups)
    blend = _blend_flat_kernel if use_kernel else _blend_flat
    return blend(server_flat, client_flat, jnp.asarray(w, jnp.float32),
                 jnp.float32(f_weight))


def aggregate(server_params, client_params, *, data_sizes, stalenesses,
              g_fn, f_weight, groups=None, use_kernel=False):
    """FedS3A global update.

    server_params: supervised model omega_s^{r+1}
    client_params: list of participating clients' models omega_i^{r_i+1}
    data_sizes:    |D_i| per participant
    stalenesses:   r - r_i per participant
    g_fn:          staleness function
    f_weight:      f(r), the dynamic supervised weight
    groups:        optional group index per participant (Eq. 10); None -> Eq. 9
    """
    data_sizes = np.asarray(data_sizes, dtype=np.float64)
    g = np.array([g_fn(s) for s in stalenesses], dtype=np.float64)

    if groups is None:
        w = data_sizes * g
        w = w / max(data_sizes.sum(), 1e-12)
        # Eq. 9: weights |D_i|/|D_c| * g(s_i) (not renormalized; g shrinks
        # stale contributions relative to the fresh ones)
        w = w / max(w.sum(), 1e-12)
        unsup = _weighted_sum_trees(client_params, w, use_kernel=use_kernel)
    else:
        groups = np.asarray(groups)
        uniq = np.unique(groups)
        group_models = []
        for gidx in uniq:
            sel = np.where(groups == gidx)[0]
            dg = data_sizes[sel]
            wg = dg * g[sel]
            wg = wg / max(wg.sum(), 1e-12)
            group_models.append(_weighted_sum_trees(
                [client_params[i] for i in sel], wg, use_kernel=use_kernel))
        w = np.full(len(group_models), 1.0 / len(group_models))
        unsup = _weighted_sum_trees(group_models, w, use_kernel=use_kernel)

    return jax.tree.map(
        lambda s, u: (f_weight * s.astype(jnp.float32) +
                      (1.0 - f_weight) * u.astype(jnp.float32)).astype(s.dtype),
        server_params, unsup)


def fedavg(client_params, data_sizes):
    """Eq. 3 (plain FedAvg over clients)."""
    w = np.asarray(data_sizes, dtype=np.float64)
    w = w / w.sum()
    return _weighted_sum_trees(client_params, w)


def fedavg_ssl(server_params, client_params, data_sizes, f_weight):
    """Eq. 8: FedAvg + dynamic supervised weight (the adapted baseline)."""
    unsup = fedavg(client_params, data_sizes)
    return jax.tree.map(
        lambda s, u: (f_weight * s.astype(jnp.float32) +
                      (1.0 - f_weight) * u.astype(jnp.float32)).astype(s.dtype),
        server_params, unsup)


def fedasync_blend(global_params, client_params, *, staleness, alpha=0.9,
                   a=0.5):
    """FedAsync [Xie et al. 2019] mixing with polynomial staleness decay
    (alpha=0.9, a=0.5 — the best-performing combination per the paper; the
    proximal rho=0.005 term lives in the client loss, handled by L2 in the
    baseline trainer)."""
    alpha_t = min(alpha * (staleness + 1.0) ** (-a), 1.0)
    return jax.tree.map(
        lambda gp, cp: ((1 - alpha_t) * gp.astype(jnp.float32) +
                        alpha_t * cp.astype(jnp.float32)).astype(gp.dtype),
        global_params, client_params)
