"""FedS3A — the paper's primary contribution: federated semi-supervised +
semi-asynchronous learning (scheduler, aggregation, pseudo-labeling,
staleness control, sparse-diff communication, fault injection, baselines)."""
from repro.core.feds3a import FedS3AConfig, FedS3ATrainer  # noqa: F401
from repro.core.param_layout import ParamLayout  # noqa: F401
from repro.core.base_store import VersionedBaseStore  # noqa: F401
from repro.core.client_store import PagedClientStore  # noqa: F401
from repro.core.scheduler import FleetStalledError  # noqa: F401
from repro.core.sparse_comm import (MALFORM_KINDS,  # noqa: F401
                                    WireIntegrityError)
from repro.core.traffic import REFERENCE_CHURN, TrafficModel  # noqa: F401
from repro.core.baselines import FedAvgSSL, FedAsyncSSL, LocalSSL  # noqa: F401
