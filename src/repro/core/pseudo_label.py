"""Client-side unsupervised training via pseudo-labeling (Eq. 5) and the
server-side supervised step (Eq. 6), for the paper's CNN.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.cnn import cnn_forward
from repro.optimizer import adam_init, adam_update


def pseudo_label_loss(cfg, params, x, *, threshold=0.95, rng=None,
                      use_kernel=True):
    """Eq. 5: mean over samples of 1[max p >= theta] * CE(argmax, p)."""
    logits = cnn_forward(cfg, params, x, train=rng is not None, rng=rng)
    if use_kernel:
        loss, mask = kops.masked_pseudo_ce(logits, threshold)
    else:
        from repro.kernels.ref import masked_pseudo_ce_ref
        loss, mask = masked_pseudo_ce_ref(logits, threshold)
    return jnp.sum(loss) / x.shape[0], jnp.sum(mask)


def supervised_loss(cfg, params, x, y, *, rng=None):
    """Eq. 6: plain cross entropy on the server's labeled data."""
    logits = cnn_forward(cfg, params, x, train=rng is not None, rng=rng)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


import functools


@functools.lru_cache(maxsize=None)
def make_client_epoch(cfg, *, batch_size=100, threshold=0.95, l1=0.0,
                      use_kernel=False):
    """One unsupervised epoch (E=1 per paper default) over a client's data.

    Data is padded to a multiple of batch_size with a validity mask so one
    jitted function serves every client size. lru_cache'd so every trainer
    (each benchmark config) shares the compiled step.
    """

    @partial(jax.jit, static_argnames=("nb",))
    def epoch(params, opt, x, valid, lr, rng, nb):
        xb = x.reshape(nb, batch_size, -1)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            params, opt, rng = carry
            xi, vi = inp
            rng, dr = jax.random.split(rng)

            def loss_fn(p):
                logits = cnn_forward(cfg, p, xi, train=True, rng=dr)
                if use_kernel:
                    loss, _ = kops.masked_pseudo_ce(logits, threshold)
                else:
                    from repro.kernels.ref import masked_pseudo_ce_ref
                    loss, _ = masked_pseudo_ce_ref(logits, threshold)
                return jnp.sum(loss * vi) / jnp.maximum(jnp.sum(vi), 1.0)

            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt = adam_update(g, opt, params, lr=lr, l1=l1)
            return (params, opt, rng), l

        (params, opt, _), losses = jax.lax.scan(step, (params, opt, rng), (xb, vb))
        return params, opt, jnp.mean(losses)

    def run(params, opt, x_np, lr, rng):
        import numpy as np
        n = len(x_np)
        nb = max((n + batch_size - 1) // batch_size, 1)
        pad = nb * batch_size - n
        x = np.concatenate([x_np, np.zeros((pad, x_np.shape[1]), x_np.dtype)]) \
            if pad else x_np
        valid = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
        return epoch(params, opt, jnp.asarray(x), jnp.asarray(valid),
                     jnp.float32(lr), rng, nb)

    return run


@functools.lru_cache(maxsize=None)
def make_server_epoch(cfg, *, batch_size=100, l1=0.0):
    @partial(jax.jit, static_argnames=("nb",))
    def epoch(params, opt, x, y, valid, lr, rng, nb):
        xb = x.reshape(nb, batch_size, -1)
        yb = y.reshape(nb, batch_size)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            params, opt, rng = carry
            xi, yi, vi = inp
            rng, dr = jax.random.split(rng)

            def loss_fn(p):
                logits = cnn_forward(cfg, p, xi, train=True, rng=dr)
                logp = jax.nn.log_softmax(logits, axis=-1)
                ce = -jnp.take_along_axis(logp, yi[:, None], axis=-1)[:, 0]
                return jnp.sum(ce * vi) / jnp.maximum(jnp.sum(vi), 1.0)

            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt = adam_update(g, opt, params, lr=lr, l1=l1)
            return (params, opt, rng), l

        (params, opt, _), losses = jax.lax.scan(step, (params, opt, rng),
                                                (xb, yb, vb))
        return params, opt, jnp.mean(losses)

    def run(params, opt, x_np, y_np, lr, rng):
        import numpy as np
        n = len(x_np)
        nb = max((n + batch_size - 1) // batch_size, 1)
        pad = nb * batch_size - n
        if pad:
            x = np.concatenate([x_np, np.zeros((pad, x_np.shape[1]), x_np.dtype)])
            y = np.concatenate([y_np, np.zeros(pad, y_np.dtype)])
        else:
            x, y = x_np, y_np
        valid = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
        return epoch(params, opt, jnp.asarray(x), jnp.asarray(y),
                     jnp.asarray(valid), jnp.float32(lr), rng, nb)

    return run


@functools.lru_cache(maxsize=None)
def predict_fn(cfg):
    @jax.jit
    def predict(params, x):
        return jnp.argmax(cnn_forward(cfg, params, x), axis=-1)
    return predict


@functools.lru_cache(maxsize=None)
def class_histogram(cfg):
    """Pseudo-label class distribution of a client (used for grouping —
    the server never sees true client labels)."""
    @jax.jit
    def hist(params, x):
        pred = jnp.argmax(cnn_forward(cfg, params, x), axis=-1)
        return jnp.bincount(pred, length=cfg.num_classes) / x.shape[0]
    return hist
