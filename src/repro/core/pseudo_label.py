"""Client-side unsupervised training via pseudo-labeling (Eq. 5) and the
server-side supervised step (Eq. 6), for the paper's CNN.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.cnn import cnn_forward
from repro.optimizer import adam_update


def pseudo_label_loss(cfg, params, x, *, threshold=0.95, rng=None,
                      use_kernel=True):
    """Eq. 5: mean over samples of 1[max p >= theta] * CE(argmax, p)."""
    logits = cnn_forward(cfg, params, x, train=rng is not None, rng=rng)
    if use_kernel:
        loss, mask = kops.masked_pseudo_ce(logits, threshold)
    else:
        from repro.kernels.ref import masked_pseudo_ce_ref
        loss, mask = masked_pseudo_ce_ref(logits, threshold)
    return jnp.sum(loss) / x.shape[0], jnp.sum(mask)


def supervised_loss(cfg, params, x, y, *, rng=None):
    """Eq. 6: plain cross entropy on the server's labeled data."""
    logits = cnn_forward(cfg, params, x, train=rng is not None, rng=rng)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


import functools


@functools.lru_cache(maxsize=None)
def make_client_epoch(cfg, *, batch_size=100, threshold=0.95, l1=0.0,
                      use_kernel=False):
    """One unsupervised epoch (E=1 per paper default) over a client's data.

    Data is padded to a multiple of batch_size with a validity mask so one
    jitted function serves every client size. lru_cache'd so every trainer
    (each benchmark config) shares the compiled step.
    """

    @partial(jax.jit, static_argnames=("nb",))
    def epoch(params, opt, x, valid, lr, rng, nb):
        xb = x.reshape(nb, batch_size, -1)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            params, opt, rng = carry
            xi, vi = inp
            rng, dr = jax.random.split(rng)

            def loss_fn(p):
                logits = cnn_forward(cfg, p, xi, train=True, rng=dr)
                if use_kernel:
                    loss, _ = kops.masked_pseudo_ce(logits, threshold)
                else:
                    from repro.kernels.ref import masked_pseudo_ce_ref
                    loss, _ = masked_pseudo_ce_ref(logits, threshold)
                return jnp.sum(loss * vi) / jnp.maximum(jnp.sum(vi), 1.0)

            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt = adam_update(g, opt, params, lr=lr, l1=l1)
            return (params, opt, rng), l

        (params, opt, _), losses = jax.lax.scan(step, (params, opt, rng), (xb, vb))
        return params, opt, jnp.mean(losses)

    def run(params, opt, x_np, lr, rng):
        import numpy as np
        n = len(x_np)
        nb = max((n + batch_size - 1) // batch_size, 1)
        pad = nb * batch_size - n
        x = np.concatenate([x_np, np.zeros((pad, x_np.shape[1]), x_np.dtype)]) \
            if pad else x_np
        valid = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
        return epoch(params, opt, jnp.asarray(x), jnp.asarray(valid),
                     jnp.float32(lr), rng, nb)

    return run


def _cnn_template(cfg):
    """Leaf shapes/dtypes of one client's parameter tree (no allocation)."""
    from repro.models.cnn import init_cnn
    return jax.eval_shape(lambda k: init_cnn(cfg, k), jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def make_batched_client_epoch(cfg, *, batch_size=100, threshold=0.95, l1=0.0,
                              use_kernel=False, epochs=1):
    """All participants' pseudo-label epochs in ONE jitted vmap-over-scan.

    Client state arrives as a (K, N) flat stack (FedJAX ``for_each_client``
    style, row i = client i's base params); unflatten to the stacked pytree,
    the fresh zeroed Adam state, the vmapped per-client scan over batches,
    and the final re-flatten all live inside the same jit, so one dispatch
    trains every participant. Per-client learning rates (K,) and RNG keys
    (K, 2) ride along as batched arrays.

    Every client's data is padded to the same ``nb`` batches; a batch with
    no valid sample is a true no-op (params, opt state and Adam ``t`` are
    carried through unchanged), so a client padded from nb_i to nb batches
    takes exactly the nb_i optimizer steps the sequential reference path
    takes — bit-for-bit comparable modulo batched matmul reduction order.
    """
    from repro.core.sparse_comm import unflatten_like

    template = _cnn_template(cfg)

    @partial(jax.jit, static_argnames=("nb",))
    def epoch(base_flat, x, valid, lrs, rngs, nb):
        def one_client(flat, xc, vc, lr, rng):
            xb = xc.reshape(nb, batch_size, -1)
            vb = vc.reshape(nb, batch_size)
            # Adam state stays flat too: elementwise updates are identical
            # math leaf-by-leaf or on the concatenated vector, and the flat
            # form is ~10 XLA ops per step instead of ~10 per leaf.
            opt = {"m": jnp.zeros_like(flat), "v": jnp.zeros_like(flat),
                   "t": jnp.zeros((), jnp.int32)}

            def step(carry, inp):
                flat, o, rng = carry
                xi, vi = inp
                rng, dr = jax.random.split(rng)

                def live_step(_):
                    def loss_fn(fp):
                        pp = unflatten_like(fp, template)
                        logits = cnn_forward(cfg, pp, xi, train=True, rng=dr)
                        if use_kernel:
                            loss, _ = kops.masked_pseudo_ce(logits, threshold)
                        else:
                            from repro.kernels.ref import masked_pseudo_ce_ref
                            loss, _ = masked_pseudo_ce_ref(logits, threshold)
                        return jnp.sum(loss * vi) / \
                            jnp.maximum(jnp.sum(vi), 1.0)

                    l, g = jax.value_and_grad(loss_fn)(flat)
                    f2, o2 = adam_update(g, o, flat, lr=lr, l1=l1)
                    return f2, o2, l

                def dead_step(_):
                    return flat, o, jnp.float32(0.0)

                # all-padding batch -> true no-op. Under lax.map (CPU) the
                # cond branches for real, so a client padded from nb_i to nb
                # batches pays for exactly nb_i steps; under vmap it lowers
                # to a select, which is still a correct no-op.
                live = jnp.sum(vi) > 0
                flat, o, l = jax.lax.cond(live, live_step, dead_step, None)
                return (flat, o, rng), (l, live)

            # Adam state persists across the client's E epochs; epoch e > 0
            # folds its index into the client key so every epoch draws fresh
            # dropout masks (epoch 0 keeps the raw key, so E=1 runs are
            # bit-identical to the pre-fold behaviour). _train_client uses
            # the same fold, keeping the engines pinned at epochs > 1 — the
            # old restart-from-the-same-key form replayed identical masks
            # every epoch in BOTH paths.
            for e in range(epochs):
                ek = rng if e == 0 else jax.random.fold_in(rng, e)
                (flat, opt, _), (losses, lives) = jax.lax.scan(
                    step, (flat, opt, ek), (xb, vb))
            return flat, jnp.sum(losses) / jnp.maximum(jnp.sum(lives), 1.0)

        # Client-axis strategy: vmap on accelerators; on XLA:CPU batched
        # GEMMs degrade superlinearly past K~4 (measured 2x at K=6), so we
        # lower the client axis to lax.map (a scan over clients inside the
        # same jitted call) there instead.
        if jax.default_backend() == "cpu":
            def all_clients(*args):
                return jax.lax.map(lambda t: one_client(*t), args)
        else:
            def all_clients(*args):
                return jax.vmap(one_client)(*args)

        return all_clients(base_flat, x, valid, lrs, rngs)

    def run(base_flat, x, valid, lrs, rngs):
        """base_flat: (K, N); x: (K, nb*B, F); valid: (K, nb*B)."""
        nb = x.shape[1] // batch_size
        return epoch(base_flat, x, valid,
                     jnp.asarray(lrs, jnp.float32), rngs, nb)

    return run


@functools.lru_cache(maxsize=None)
def make_server_epoch(cfg, *, batch_size=100, l1=0.0):
    @partial(jax.jit, static_argnames=("nb",))
    def epoch(params, opt, x, y, valid, lr, rng, nb):
        xb = x.reshape(nb, batch_size, -1)
        yb = y.reshape(nb, batch_size)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            params, opt, rng = carry
            xi, yi, vi = inp
            rng, dr = jax.random.split(rng)

            def loss_fn(p):
                logits = cnn_forward(cfg, p, xi, train=True, rng=dr)
                logp = jax.nn.log_softmax(logits, axis=-1)
                ce = -jnp.take_along_axis(logp, yi[:, None], axis=-1)[:, 0]
                return jnp.sum(ce * vi) / jnp.maximum(jnp.sum(vi), 1.0)

            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt = adam_update(g, opt, params, lr=lr, l1=l1)
            return (params, opt, rng), l

        (params, opt, _), losses = jax.lax.scan(step, (params, opt, rng),
                                                (xb, yb, vb))
        return params, opt, jnp.mean(losses)

    def run(params, opt, x_np, y_np, lr, rng):
        import numpy as np
        n = len(x_np)
        nb = max((n + batch_size - 1) // batch_size, 1)
        pad = nb * batch_size - n
        if pad:
            x = np.concatenate([x_np, np.zeros((pad, x_np.shape[1]), x_np.dtype)])
            y = np.concatenate([y_np, np.zeros(pad, y_np.dtype)])
        else:
            x, y = x_np, y_np
        valid = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
        return epoch(params, opt, jnp.asarray(x), jnp.asarray(y),
                     jnp.asarray(valid), jnp.float32(lr), rng, nb)

    return run


@functools.lru_cache(maxsize=None)
def make_server_epoch_flat(cfg, *, batch_size=100, l1=0.0):
    """Flat-state twin of ``make_server_epoch`` for the batched engine.

    Takes/returns the global model and the server's Adam state as flat
    vectors (trees materialize only inside the loss), so the server step
    composes with the flat round pipeline without per-round tree round
    trips. Elementwise Adam math is identical leaf-by-leaf or flat, so this
    matches the sequential server epoch to float reduction order.
    """
    from repro.core.sparse_comm import unflatten_like

    template = _cnn_template(cfg)

    @partial(jax.jit, static_argnames=("nb",))
    def epoch(flat, opt, x, y, valid, lr, rng, nb):
        xb = x.reshape(nb, batch_size, -1)
        yb = y.reshape(nb, batch_size)
        vb = valid.reshape(nb, batch_size)

        def step(carry, inp):
            flat, opt, rng = carry
            xi, yi, vi = inp
            rng, dr = jax.random.split(rng)

            def loss_fn(fp):
                p = unflatten_like(fp, template)
                logits = cnn_forward(cfg, p, xi, train=True, rng=dr)
                logp = jax.nn.log_softmax(logits, axis=-1)
                ce = -jnp.take_along_axis(logp, yi[:, None], axis=-1)[:, 0]
                return jnp.sum(ce * vi) / jnp.maximum(jnp.sum(vi), 1.0)

            l, g = jax.value_and_grad(loss_fn)(flat)
            flat, opt = adam_update(g, opt, flat, lr=lr, l1=l1)
            return (flat, opt, rng), l

        (flat, opt, _), losses = jax.lax.scan(step, (flat, opt, rng),
                                              (xb, yb, vb))
        return flat, opt, jnp.mean(losses)

    def run(flat, opt, x_np, y_np, lr, rng):
        import numpy as np
        n = len(x_np)
        nb = max((n + batch_size - 1) // batch_size, 1)
        pad = nb * batch_size - n
        if pad:
            x = np.concatenate([x_np, np.zeros((pad, x_np.shape[1]),
                                               x_np.dtype)])
            y = np.concatenate([y_np, np.zeros(pad, y_np.dtype)])
        else:
            x, y = x_np, y_np
        valid = np.concatenate([np.ones(n, np.float32),
                                np.zeros(pad, np.float32)])
        return epoch(flat, opt, jnp.asarray(x), jnp.asarray(y),
                     jnp.asarray(valid), jnp.float32(lr), rng, nb)

    return run


@functools.lru_cache(maxsize=None)
def predict_fn(cfg):
    @jax.jit
    def predict(params, x):
        return jnp.argmax(cnn_forward(cfg, params, x), axis=-1)
    return predict


@functools.lru_cache(maxsize=None)
def class_histogram(cfg):
    """Pseudo-label class distribution of a client (used for grouping —
    the server never sees true client labels)."""
    @jax.jit
    def hist(params, x):
        pred = jnp.argmax(cnn_forward(cfg, params, x), axis=-1)
        return jnp.bincount(pred, length=cfg.num_classes) / x.shape[0]
    return hist


@functools.lru_cache(maxsize=None)
def class_histogram_batch(cfg, *, batch_size=100):
    """Batched ``class_histogram`` over padded per-client data.

    flat: (K, N) stacked uploaded models; x: (K, nb*B, F); valid: (K, nb*B)
    0/1 — padding rows are excluded from both the counts and the denominator,
    so each row matches the sequential histogram on that client's unpadded
    data. The forward runs chunk-by-chunk with all-padding chunks skipped
    (a real branch under the CPU lax.map strategy).
    """
    from repro.core.sparse_comm import unflatten_stacked

    template = _cnn_template(cfg)

    def hist(p, x, valid):
        xb = x.reshape(-1, batch_size, x.shape[-1])
        vb = valid.reshape(-1, batch_size)

        def step(acc, inp):
            xi, vi = inp
            counts = jax.lax.cond(
                jnp.sum(vi) > 0,
                lambda _: jnp.zeros(cfg.num_classes, jnp.float32)
                .at[jnp.argmax(cnn_forward(cfg, p, xi), axis=-1)].add(vi),
                lambda _: jnp.zeros(cfg.num_classes, jnp.float32), None)
            return acc + counts, None

        acc, _ = jax.lax.scan(step, jnp.zeros(cfg.num_classes, jnp.float32),
                              (xb, vb))
        return acc / jnp.maximum(jnp.sum(valid), 1.0)

    if jax.default_backend() == "cpu":
        def mapped(params, x, valid):
            return jax.lax.map(lambda t: hist(*t), (params, x, valid))
    else:
        def mapped(params, x, valid):
            return jax.vmap(hist)(params, x, valid)

    @jax.jit
    def run(flat, x, valid):
        params = unflatten_stacked(flat, template)
        return mapped(params, x, valid)

    return run
