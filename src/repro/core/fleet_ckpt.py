"""Crash-consistent fleet checkpointing: atomic, checksummed, restartable.

One checkpoint is a directory ``<root>/ckpt-{round:08d}/`` holding one
msgpack section file per state owner (trainer tensors, scheduler heaps,
base-store ring, comm ledgers, paged client pages, round logs) plus a
``MANIFEST.msgpack`` carrying a sha256 digest of every section and the
trainer's configuration fingerprint. Write protocol:

1. section files are written directly (no per-file fsync or rename):
   until the manifest lands the whole directory is uncommitted, and the
   manifest digests make a section that was torn mid-write or never
   reached disk indistinguishable from bit-rot — restore detects it
   instead of trusting it. Per-section durability ceremony buys nothing
   that validation does not already give, and fsync-per-file is
   otherwise the entire cost of a save;
2. the MANIFEST is written LAST, by tmp + fsync + rename — it is the
   single commit (and durability) point. A crash at any earlier moment
   leaves a directory with no (or a stale) manifest, or a manifest
   whose digests do not match the files on disk; a power cut at worst
   invalidates the newest checkpoint, which restore skips;
3. retention prunes all but the newest ``keep`` checkpoints — the
   previous good checkpoint survives precisely so an invalidated newest
   write has a fallback. Directory entries are not fsynced: against
   SIGKILL (the primary threat model — the kernel keeps dirty pages) a
   committed checkpoint is always visible, and a power cut that loses
   the rename at worst hides the newest checkpoint, which is the same
   graceful fallback as every other torn-write shape above.

Restore (:func:`find_restorable`) scans checkpoints newest-first and
returns the first whose manifest parses and whose every section matches
its digest — a torn or bit-rotted newest checkpoint falls back to the
previous good one instead of poisoning the resume. The subprocess
kill-resume suite (tests/test_kill_resume.py) SIGKILLs a training run
mid-round and pins the restored twin bit-identical to an uninterrupted
run.

Serialization is a small self-describing encoding on top of msgpack:
numpy/JAX arrays keep dtype+shape+raw bytes, dicts keep non-string keys
(scheduler version maps, staleness logs), and integers wider than 64
bits — the 128-bit PCG64 state words inside ``np.random.Generator``
snapshots — ride as tagged hex strings, so RNG stream positions restore
exactly.
"""
from __future__ import annotations

import hashlib
import os
import re
import shutil

import msgpack
import numpy as np

MANIFEST_NAME = "MANIFEST.msgpack"
FORMAT_VERSION = 1
_CKPT_RE = re.compile(r"^ckpt-(\d{8})$")
# msgpack packs ints in [-2^63, 2^64); anything wider is tagged hex
_INT_LO, _INT_HI = -(1 << 63), 1 << 64


# -- value encoding ---------------------------------------------------------
class Lazy:
    """A value whose host materialization is deferred to serialization
    time: ``fn`` is a thunk closed over IMMUTABLE state (device arrays,
    already-copied host numbers) that :func:`pack` resolves when it
    encodes. Lets a snapshot taken on the training thread avoid blocking
    on in-flight device work — the checkpoint writer thread pays the
    sync instead."""
    __slots__ = ("fn",)

    def __init__(self, fn):
        self.fn = fn


def _encode(obj):
    """Lower ``obj`` to msgpack-packable types, recursively, reversibly."""
    if isinstance(obj, Lazy):
        return _encode(obj.fn())
    if obj is None or isinstance(obj, (bool, str, bytes)):
        return obj
    if isinstance(obj, int):
        if _INT_LO <= obj < _INT_HI:
            return obj
        return {"__big__": hex(obj)}
    if isinstance(obj, float):
        return obj
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return _encode(obj.item())
    if isinstance(obj, dict):
        return {"__map__": [[_encode(k), _encode(v)]
                            for k, v in obj.items()]}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    # anything array-like (numpy, JAX device arrays) lands here
    arr = np.asarray(obj)
    return {"__nd__": {"dtype": str(arr.dtype), "shape": list(arr.shape),
                       "data": np.ascontiguousarray(arr).tobytes()}}


def _decode(obj):
    if isinstance(obj, dict):
        if "__big__" in obj:
            return int(obj["__big__"], 16)
        if "__nd__" in obj:
            d = obj["__nd__"]
            arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
            return arr.reshape(d["shape"]).copy()
        if "__map__" in obj:
            return {_decode(k): _decode(v) for k, v in obj["__map__"]}
        raise ValueError(f"unknown tagged object with keys {sorted(obj)}")
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def pack(obj) -> bytes:
    return msgpack.packb(_encode(obj), use_bin_type=True)


class PrePacked:
    """A section already encoded to msgpack bytes — or a thunk producing
    them, resolved at write time (so a background writer can pay the
    encoding cost); :func:`write_checkpoint` stores the bytes verbatim."""
    __slots__ = ("_src",)

    def __init__(self, src):
        self._src = src

    @property
    def data(self) -> bytes:
        return self._src() if callable(self._src) else self._src


def pack_array_of_packed(items):
    """A msgpack array assembled from already-:func:`pack`-ed element
    bytes. msgpack is context-free, so concatenation under an array
    header is byte-identical to ``pack`` of the whole list and
    :func:`unpack` reads it back as a normal list — which lets an
    append-only history (the round logs) be encoded once per ELEMENT
    over a run instead of once per checkpoint, keeping save cost flat
    instead of growing with the round index."""
    n = len(items)
    if n < 16:
        header = bytes([0x90 | n])
    elif n < 1 << 16:
        header = b"\xdc" + n.to_bytes(2, "big")
    else:
        header = b"\xdd" + n.to_bytes(4, "big")
    return header + b"".join(items)


def unpack(data: bytes):
    return _decode(msgpack.unpackb(data, raw=False, strict_map_key=False))


# -- atomic file protocol ---------------------------------------------------
def _write_atomic(path, data: bytes):
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def checkpoint_dirs(root):
    """All checkpoint directories under ``root`` as (round, path),
    ascending by round. Tolerates a missing root (no checkpoints yet)."""
    out = []
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return out
    for name in names:
        m = _CKPT_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(root, name)))
    out.sort()
    return out


def write_checkpoint(root, round_no, sections, fingerprint, *, keep=2):
    """Write one checkpoint atomically; returns its directory path.

    ``sections`` maps section name -> serializable state dict. The
    MANIFEST (digests + ``fingerprint`` + ``round``) commits the write;
    until it lands, :func:`find_restorable` does not see this checkpoint.
    Retention then drops all but the newest ``keep`` checkpoints (the
    previous good one survives precisely so a torn NEXT write has a
    fallback).
    """
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, f"ckpt-{int(round_no):08d}")
    os.makedirs(path, exist_ok=True)
    files = {}
    for name, obj in sections.items():
        data = obj.data if isinstance(obj, PrePacked) else pack(obj)
        fname = f"{name}.msgpack"
        # plain write, no fsync/rename: the digest below catches a torn or
        # undurable section, and the fsynced manifest is the commit point
        with open(os.path.join(path, fname), "wb") as f:
            f.write(data)
        files[fname] = hashlib.sha256(data).hexdigest()
    manifest = {"format": FORMAT_VERSION, "round": int(round_no),
                "files": files, "fingerprint": fingerprint}
    _write_atomic(os.path.join(path, MANIFEST_NAME), pack(manifest))
    for _, old in checkpoint_dirs(root)[:-max(int(keep), 1)]:
        shutil.rmtree(old, ignore_errors=True)
    return path


def validate_checkpoint(path):
    """Manifest dict if the checkpoint at ``path`` is complete and every
    section matches its recorded digest; ``None`` for torn / corrupted /
    uncommitted checkpoints (missing manifest, unparseable manifest,
    missing section, digest mismatch)."""
    try:
        with open(os.path.join(path, MANIFEST_NAME), "rb") as f:
            manifest = unpack(f.read())
    except (OSError, ValueError, msgpack.UnpackException):
        return None
    if not isinstance(manifest, dict) or "files" not in manifest:
        return None
    for fname, digest in manifest["files"].items():
        try:
            with open(os.path.join(path, fname), "rb") as f:
                data = f.read()
        except OSError:
            return None
        if hashlib.sha256(data).hexdigest() != digest:
            return None
    return manifest


def find_restorable(root):
    """Newest valid checkpoint under ``root`` as (path, manifest), or
    (None, None). Scans newest-first: a torn latest write falls back to
    the previous good checkpoint."""
    for _, path in reversed(checkpoint_dirs(root)):
        manifest = validate_checkpoint(path)
        if manifest is not None:
            return path, manifest
    return None, None


def read_section(path, name):
    """Load one section of a checkpoint directory."""
    with open(os.path.join(path, f"{name}.msgpack"), "rb") as f:
        return unpack(f.read())
