"""Staleness-windowed versioned base store (§IV-C2 distribution).

The paper's staleness-tolerant distribution bounds every in-flight client to
within ``tau`` versions of the global model, so the server never needs a
per-client copy of anybody's base model: at most ``tau + 2`` distinct global
versions can be referenced at once (versions ``r - tau .. r`` by in-flight
runs, plus ``r - tau - 1`` transiently by clients about to be force-restarted
at the round boundary).  This module exploits that invariant:

* a **ring buffer** of the last ``tau + 2`` canonical flat reconstructions
  ``R_v`` (slot ``v % (tau + 2)``), where ``R_0`` is the warmed-up initial
  model and ``R_{v+1} = R_v + decode(chain_v+1)``;
* one compacted **CSR chain delta** per retained round transition
  ``v -> v+1`` — the actual (values, indices) payload every client moving
  past that transition receives, so clients that share a ``base_version``
  hold the bit-identical reconstruction by construction;
* a per-client ``base_version`` integer array.

The per-client arrays (``client_version``, ``detached``) are HOST-side
numpy, never device-resident: version bookkeeping is boundary-time python
anyway, and keeping them on host is what lets the paged client store
(``core.client_store``) report a complete host-side per-client footprint —
it adopts references to these arrays rather than copying them.

Server memory is ``O(tau * N + M)`` — the ``(M, N)`` dense base matrices the
engines previously kept are gone — and distribution becomes a **chain-delta
broadcast**: each transition payload goes on the wire once per round and a
client at stale version ``v`` picks up the suffix ``v+1 ..`` it needs, so a
round transmits at most ``tau + 1`` payloads (the suffix from the stalest
target's version) instead of one per-client encode per target.  At ``K``
targets per round that cuts distribution bytes roughly ``K``-fold.

Numerics: with sparsification enabled the chain reconstruction ``R_v`` is a
*canonical lossy* approximation of the aggregated global model ``G_v`` — the
same one for every client — whereas the legacy dense store accumulated a
*per-client* lossy approximation (each client's base absorbed its own
encode-against-own-base error).  With ``sparse_comm=False`` every chain
delta is exact, ``R_v == G_v`` bit-for-bit, and the versioned store
reproduces the dense store exactly (pinned by tests/test_base_store.py).

Accounting is deferred like ``SparseComm``'s: chain stored-counts stay
device scalars; ``dist_payload_bytes()`` materializes on read.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_ring_set = None


def _set_row(ring, slot, row):
    """ring.at[slot].set(row) under one cached jit (slot is a traced int,
    so every slot shares the compile)."""
    global _ring_set
    if _ring_set is None:
        _ring_set = jax.jit(lambda r, s, x: r.at[s].set(x))
    return _ring_set(ring, jnp.int32(slot), row)


_gather = None


def _gather_rows(ring, slots):
    global _gather
    if _gather is None:
        _gather = jax.jit(lambda r, s: r[s])
    return _gather(ring, slots)


_totals = {}


def _payload_total(scalars):
    """sum(stored) element count over a round's chain-suffix payloads in
    ONE jitted dispatch (cached per arity) — the comm channel converts the
    count to bytes at its wire format's per-element widths. The stored
    counts come out of the sharded stages fully replicated, and every
    eager op on a replicated array costs ~1.5 ms of multi-device dispatch
    on CPU — folding the stack/sum into one call keeps the per-round
    broadcast accounting at a single dispatch."""
    n = len(scalars)
    fn = _totals.get(n)
    if fn is None:
        fn = jax.jit(lambda *s: jnp.sum(jnp.stack(s)))
        _totals[n] = fn
    return fn(*scalars)


class VersionedBaseStore:
    """Ring of ``tau + 2`` canonical reconstructions + chain deltas.

    The trainer computes each round's transition payload inside its own
    jitted round stage (the encode fuses with the aggregation blend) and
    hands the result to :meth:`advance`; :meth:`account_distribution` then
    books the per-version broadcast onto the trainer's ``SparseComm``.
    """

    def __init__(self, global_flat, M, tau):
        self.n = int(global_flat.shape[0])
        self.M = int(M)
        self.tau = int(tau)
        self.depth = self.tau + 2
        self.ring = jnp.broadcast_to(
            jnp.asarray(global_flat, jnp.float32), (self.depth, self.n))
        self._latest = jnp.asarray(global_flat, jnp.float32)
        # which version each ring slot currently holds (-1 = never written)
        self.slot_version = np.full(self.depth, -1, np.int64)
        self.slot_version[0] = 0
        self.client_version = np.zeros(self.M, np.int64)
        # offline (churned-out) clients: their parked version no longer
        # constrains ring eviction — on rejoin they are either served the
        # chain suffix (version still in-window) or an explicit full-model
        # resync (version evicted while they were away)
        self.detached = np.zeros(self.M, bool)
        self.version = 0
        # version v -> payload of transition v-1 -> v:
        #   {"stored": device-scalar-or-int[, "vals": (cap,), "idx": (cap,)]}
        # (csr_q: the quantized wire arrays instead —
        #   {"stored", "qvals" int8|f16, "qoffs" int16, "qcnt" int16,
        #    "scale" f32} — the ring reconstruction already folded in the
        # dequantized decode, so replaying the chain stays canonical f32)
        self._chain = {}
        self._dist_pending = []      # (count device scalar, bytes/element)
        self._dist_host = 0.0

    # -- lookups -----------------------------------------------------------
    def slot(self, version):
        return int(version) % self.depth

    def slots_for(self, client_ids):
        """(K,) int32 ring-slot index per client — the version-indexed
        gather ``ring[slots]`` replaces the dense (M, N) row gather."""
        return jnp.asarray(self.client_version[np.asarray(client_ids)]
                           % self.depth, jnp.int32)

    def gather(self, client_ids):
        """(K, N) base rows for ``client_ids`` — a ring lookup, not a
        per-client state read: same-version clients get the same row."""
        return _gather_rows(self.ring, self.slots_for(client_ids))

    def latest(self):
        """R_version — the canonical reconstruction of the newest global.
        Cached at :meth:`advance` so reading it per round costs no ring
        gather (an eager multi-device op the sharded engine would pay every
        round)."""
        return self._latest

    # -- round transition --------------------------------------------------
    def advance(self, new_recon, payload, new_version):
        """Install ``R_{new_version}`` and its chain payload.

        ``payload``: {"stored": count[, "vals", "idx"]} for the transition
        ``new_version - 1 -> new_version`` (counts may be device scalars —
        nothing syncs here).  Raises if the evicted ring slot still holds a
        version some client references: by the scheduler's tau-forcing
        invariant that can never happen, so a raise means the staleness
        window was violated upstream.
        """
        if new_version != self.version + 1:
            raise ValueError(f"advance must be sequential: at version "
                             f"{self.version}, got {new_version}")
        slot = self.slot(new_version)
        evicted = self.slot_version[slot]
        if evicted >= 0 and bool(
                ((self.client_version == evicted) & ~self.detached).any()):
            raise RuntimeError(
                f"ring eviction would drop version {evicted} still "
                f"referenced by an attached client (window depth "
                f"{self.depth}, new version {new_version})")
        self.ring = _set_row(self.ring, slot, new_recon)
        self._latest = new_recon
        self.slot_version[slot] = new_version
        self.version = new_version
        self._chain[new_version] = payload
        # transitions older than the deepest possible suffix can never be
        # re-broadcast again: the stalest distribution target is a forced
        # client at version new - tau - 1, whose suffix starts at
        # new - tau — so exactly tau + 1 chain entries stay live
        for v in [v for v in self._chain if v < new_version - self.tau]:
            del self._chain[v]

    # -- churn -------------------------------------------------------------
    def detach(self, client_ids):
        """Park departed clients: their version stays recorded (a rejoiner
        inside the staleness window is served the chain suffix it missed)
        but stops constraining ring eviction — an offline client must never
        wedge the fleet's window."""
        ids = np.asarray(sorted(set(int(i) for i in client_ids)), np.int64)
        if ids.size:
            self.detached[ids] = True

    def split_rejoined(self, client_ids, new_version):
        """Partition rejoining clients by how they can be re-based at the
        ``new_version`` boundary: ``(chain_ids, resync_ids)``.

        A rejoiner parked at version ``v`` needs the transition suffix
        ``v+1 .. new_version``; the chain retains transitions
        ``>= new_version - tau`` after :meth:`advance` prunes, so the
        suffix exists iff ``v >= new_version - tau - 1``. Anyone staler
        was evicted from the ring while away and needs the full model.
        """
        chain, resync = [], []
        for i in sorted(set(int(c) for c in client_ids)):
            if self.client_version[i] >= new_version - self.tau - 1:
                chain.append(i)
            else:
                resync.append(i)
        return chain, resync

    def resync(self, comm, client_ids):
        """Serve rejoiners whose parked version left the ring an explicit
        full-model payload — ``n * 4`` bytes on the wire per client (a
        dense unicast; the chain broadcast cannot reach them), never
        silently free — and re-attach them at the current version."""
        ids = np.asarray(sorted(set(int(i) for i in client_ids)), np.int64)
        if ids.size == 0:
            return
        comm.account_dense_payload(float(ids.size) * self.n * 4, self.n,
                                   int(ids.size))
        self._dist_host += float(ids.size) * self.n * 4
        self.client_version[ids] = self.version
        self.detached[ids] = False

    def account_distribution(self, comm, targets):
        """Book this round's chain-delta broadcast onto ``comm``.

        Each transition payload goes on the wire ONCE per round however
        many clients listen: a client at stale version ``v`` picks the
        suffix ``v+1 .. version`` out of the broadcast, so the round's
        broadcast set is the union of the targets' suffixes — the single
        suffix from the stalest target's version, at most ``tau + 1``
        payloads.  Then bumps the targets to the new version.

        With sparsification disabled every chain payload is the full dense
        model, so a stale client only needs the newest one: the broadcast
        collapses to ONE dense payload per round.
        """
        targets = np.asarray(sorted(set(int(t) for t in targets)), np.int64)
        if targets.size:
            vers = self.client_version[targets]
            if (vers >= self.version).any():
                raise ValueError("distribution target already at (or past) "
                                 "the current version")
            if not comm.enabled:
                comm.account_batch(None, self.n, 1)
                self._dist_host += self.n * 4
            else:
                stored = [self._chain[t]["stored"]
                          for t in range(int(vers.min()) + 1,
                                         self.version + 1)]
                total = _payload_total(stored)       # one dispatch
                self._dist_pending.append((total, sum(comm.elem_bytes())))
                csr = comm.wire_format in ("csr", "csr_q")
                comm.account_payload(
                    total, self.n, len(stored),
                    row_ptr_rows=len(stored) if csr else 0)
                if csr:
                    sb, bb = comm.row_overhead_bytes(self.n)
                    self._dist_host += 4 * (len(stored) + 1) + \
                        (sb + bb) * len(stored)
            self.client_version[targets] = self.version
            self.detached[targets] = False

    # -- checkpoint / restore ----------------------------------------------
    def state_dict(self, *, defer=False):
        """Complete mutable state: the reconstruction ring, chain payloads
        (device arrays and stored-count scalars materialized to host —
        value-neutral, counts are exact integers and the deferred byte fold
        is order-preserving), per-client versions and the detached mask.
        Arrays come back as numpy; the caller owns serialization.

        ``defer=True`` (the checkpoint writer path) blocks on NOTHING:
        immutable device arrays are returned by reference and every
        host materialization — the stored counts and the pending
        distribution-byte fold — is wrapped in :class:`fleet_ckpt.Lazy`
        over references captured now, so the writer thread pays the
        device sync and the value is bit-identical to the eager fold
        (same entries, same order, same float64 host arithmetic). The
        live store's pending list is left untouched."""
        from repro.core import fleet_ckpt
        if defer:
            base = float(self._dist_host)
            pend = list(self._dist_pending)

            def _dist():
                # per-element np.asarray: the writer thread must never
                # LAUNCH device programs (a jnp.stack dispatched
                # concurrently with the training thread's multi-device
                # round can interleave collective rendezvous and deadlock
                # XLA:CPU) — transfers only. Counts are exact integers, so
                # the float64 fold matches the eager stack path exactly.
                out = base
                for cnt, eb in pend:
                    out += float(np.asarray(cnt)) * eb
                return out

            dist = fleet_ckpt.Lazy(_dist)

            def conv(k, arr):
                if k == "stored":
                    return fleet_ckpt.Lazy(
                        lambda a=arr: int(np.asarray(a)))
                return arr

            ring, latest = self.ring, self._latest
        else:
            self.dist_payload_bytes()       # fold pending device scalars
            dist = float(self._dist_host)

            def conv(k, arr):
                return int(np.asarray(arr)) if k == "stored" \
                    else np.asarray(arr)

            ring, latest = np.asarray(self.ring), np.asarray(self._latest)
        chain = []
        for v in sorted(self._chain):
            entry = {k: conv(k, arr) for k, arr in self._chain[v].items()}
            chain.append([int(v), entry])
        return {"n": self.n, "M": self.M, "tau": self.tau,
                "ring": ring,
                "latest": latest,
                "slot_version": self.slot_version.copy(),
                "client_version": self.client_version.copy(),
                "detached": self.detached.copy(),
                "version": int(self.version),
                "chain": chain,
                "dist_host": dist}

    def load_state_dict(self, d):
        """Restore :meth:`state_dict` output onto a store built with the
        same geometry (n / M / tau are checked)."""
        for k in ("n", "M", "tau"):
            if int(d[k]) != getattr(self, k):
                raise ValueError(f"base-store state has {k}={d[k]}, this "
                                 f"store has {k}={getattr(self, k)}")
        self.ring = jnp.asarray(np.asarray(d["ring"]), jnp.float32)
        self._latest = jnp.asarray(np.asarray(d["latest"]), jnp.float32)
        self.slot_version = np.asarray(d["slot_version"],
                                       np.int64).reshape(self.depth).copy()
        self.client_version = np.asarray(d["client_version"],
                                         np.int64).reshape(self.M).copy()
        self.detached = np.asarray(d["detached"],
                                   bool).reshape(self.M).copy()
        self.version = int(d["version"])
        self._chain = {int(v): dict(entry) for v, entry in d["chain"]}
        self._dist_pending = []
        self._dist_host = float(d["dist_host"])

    # -- reporting ---------------------------------------------------------
    def dist_payload_bytes(self):
        """Cumulative distribution bytes-on-wire (broadcast payloads only,
        uploads excluded). Materializes pending device scalars on read."""
        if self._dist_pending:
            counts = np.asarray(jnp.stack(
                [c for c, _ in self._dist_pending]), np.float64)
            for cnt, (_, eb) in zip(counts, self._dist_pending):
                self._dist_host += float(cnt) * eb
            self._dist_pending = []
        return self._dist_host

    def bytes(self):
        """Server memory held by the base store: the reconstruction ring
        (O(tau * N)), the retained chain payloads (O(tau * cap)) and the
        per-client version array (O(M)) — the ``O(M * N)`` dense base state
        this store replaces appears nowhere."""
        total = (self.ring.size * 4 + self.client_version.nbytes
                 + self.detached.nbytes)
        for p in self._chain.values():
            for k, arr in p.items():
                if k == "stored":
                    total += 4                           # stored count
                else:   # payload arrays at their actual dtype widths
                    total += int(arr.size) * arr.dtype.itemsize
        return int(total)
