"""Sparse-difference transmission (§IV-F) + ACO accounting.

Clients upload delta = omega_new - omega_base as a magnitude-thresholded
sparse payload; the server reconstructs omega_base + delta. The same path is
used server->client after aggregation. ACO (average communication overhead)
= payload bytes / dense bytes, matching the paper's "ratio of data
communicated to total model parameters"; sparse payload counts value+index
per nonzero (8 bytes vs 4 dense).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


@jax.jit
def _sampled_quantile(flat, q):
    """Quantile of |flat| from a strided 64k sample (exact sort over 5M params
    per message dominated benchmark wall time)."""
    n = flat.shape[0]
    stride = max(n // 65536, 1)
    return jnp.quantile(jnp.abs(flat[::stride]), q)


@jax.jit
def _mask_count(flat, thr):
    keep = jnp.abs(flat) >= thr
    return jnp.where(keep, flat, 0), jnp.sum(keep)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def flatten_tree(tree):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat


def unflatten_like(flat, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    idx = 0
    for l in leaves:
        n = l.size
        out.append(flat[idx:idx + n].reshape(l.shape).astype(l.dtype))
        idx += n
    return jax.tree_util.tree_unflatten(treedef, out)


class SparseComm:
    """Stateful comm channel with ACO bookkeeping.

    ``threshold`` modes:
      float   — absolute magnitude threshold (the paper's L1+threshold form)
      "p<frac>" — keep the top <frac> fraction by magnitude (quantile mode);
                  default p0.2 reproduces the paper's ~0.49 ACO exactly
                  (payload = nnz * 8 bytes vs dense 4 bytes/param).
    """

    def __init__(self, threshold="p0.2", *, use_kernel=True, enabled=True):
        self.threshold = threshold
        self.use_kernel = use_kernel
        self.enabled = enabled
        self.payload_bytes = 0
        self.dense_bytes = 0
        self.messages = 0

    def _abs_threshold(self, flat):
        if isinstance(self.threshold, str) and self.threshold.startswith("p"):
            frac = float(self.threshold[1:])
            return float(_sampled_quantile(flat, 1.0 - frac))
        return float(self.threshold)

    def encode(self, new_params, base_params, residual=None):
        """Returns (sparse_delta_tree, stats[, residual']). ACO accounted.

        ``residual``: error-feedback state (beyond-paper): the masked-out
        part of every previous delta is carried forward and re-offered next
        round, so sparsification error does not accumulate into model drift
        (Karimireddy et al.-style EF). Pass a zero tree to enable; the new
        residual is returned alongside.
        """
        delta = tree_sub(new_params, base_params)
        if residual is not None:
            delta = tree_add(delta, residual)
        flat = flatten_tree(delta)
        n = flat.shape[0]
        if not self.enabled:
            self.payload_bytes += n * 4
            self.dense_bytes += n * 4
            self.messages += 1
            out = (delta, {"nnz": n, "total": n})
            return out + (jax.tree.map(jnp.zeros_like, delta),) \
                if residual is not None else out
        thr = self._abs_threshold(flat)
        if self.use_kernel:
            masked, nnz_blocks = kops.sparse_delta(flat, thr)
            nnz = int(jnp.sum(nnz_blocks))
        else:
            masked, nnz = _mask_count(flat, thr)
            nnz = int(nnz)
        self.payload_bytes += nnz * 8          # fp32 value + int32 index
        self.dense_bytes += n * 4
        self.messages += 1
        sparse_tree = unflatten_like(masked, delta)
        if residual is not None:
            new_residual = unflatten_like(flat - masked, delta)
            return sparse_tree, {"nnz": nnz, "total": n}, new_residual
        return sparse_tree, {"nnz": nnz, "total": n}

    def apply(self, base_params, sparse_delta_tree):
        return tree_add(base_params, sparse_delta_tree)

    @property
    def aco(self) -> float:
        return self.payload_bytes / self.dense_bytes if self.dense_bytes else 0.0
