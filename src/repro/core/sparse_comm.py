"""Sparse-difference transmission (§IV-F) + ACO accounting.

Clients upload delta = omega_new - omega_base as a magnitude-thresholded
sparse payload; the server reconstructs omega_base + delta. The same path is
used server->client after aggregation. ACO (average communication overhead)
= payload bytes / dense bytes, matching the paper's "ratio of data
communicated to total model parameters".

Wire formats (``wire_format=``):

* ``"csr"`` (default) — the compacted wire format: each message is the CSR
  triple (values f32, column indices int32, row_ptr) actually materialized
  by the compaction kernel/oracle, so reported bytes-on-wire IS the size of
  the arrays that would cross the network: ``stored_nnz * 8 + 4 * (K + 1)``
  for a K-row batch. Exact zeros never go on the wire (they carry no
  information), and each row is bounded by a static capacity
  ``cap = min(N, ceil(cap_factor * keep_frac * N))`` (absolute-threshold
  mode: ``cap = N``); overflow past the capacity spills into the
  error-feedback residual when EF is on, and is dropped (the paper's lossy
  scheme) otherwise. Under EF the residual itself is kept as a
  capacity-bounded CSR row (top ``residual_frac`` of N by magnitude via a
  per-row sampled quantile, then the same column-order capacity rule) — the
  store is O(cap), not O(N), and ``residual_frac=1.0`` recovers lossless EF.
* ``"csr_q"`` — the quantized + packed CSR format: same compaction pipeline,
  but values ship as int8 with a per-row absmax scale (``q_dtype="fp16"``
  falls back to float16 for deltas whose dynamic range int8 cannot hold) and
  column indices ship as int16 in-block offsets plus a per-row
  ``ceil(n/512)``-entry int16 block-count table (csr_compact's stage-1
  per-block nnz, reused as the index decoder's side information). Bytes per
  stored element drop 8 -> 3 (int8: 1 value + 2 offset; fp16: 4), plus
  4 bytes/row of scale and ``2 * ceil(n/512)`` bytes/row of block table.
  Quantization is LOSSY; the encode core computes everything downstream —
  the server decode, the distribution chain, and crucially the
  error-feedback residual — from the dequantized payload, so the rounding
  error folds into the same residual that already absorbs sparsification
  overflow and is re-offered next round instead of accumulating into drift.
  Without EF the rounding error is dropped, exactly like sub-threshold mass
  in the paper's lossy scheme. The f32 ``"csr"`` format stays the
  parity-pinned reference.
* ``"dense_masked"`` — the pre-compaction reference format: the masked dense
  delta moves between engines and ACO counts value+index per threshold
  survivor (8 bytes vs 4 dense) without materializing a payload.

ACO accounting is *deferred*: payload byte counts depend on the on-device
nnz reduction, so ``encode`` / ``encode_batch`` only append the device
scalar to a pending list — no ``int()`` / ``float()`` host sync per message.
(row_ptr bytes are host-computable — 4 * (rows + 1) per batch — and tracked
as a plain int.) The ``aco`` / ``payload_bytes`` properties materialize the
pending scalars in one device->host transfer when read (typically once per
``train()``). Quantile thresholds likewise stay on device (vmapped
``_sampled_quantile`` feeding the kernel as a runtime input), so the batched
path dispatches each round's entire upload set with zero host round trips.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.sparse_delta import local_quantile_thresholds


@jax.jit
def _sampled_quantile(flat, q):
    """Quantile of |flat| from a strided 2k sample (exact sort over 5M params
    per message dominated benchmark wall time; XLA:CPU sorts are slow enough
    that even a 64k sample per message was the next bottleneck — a 2048
    sample keeps the kept-fraction standard error under ~1%)."""
    n = flat.shape[0]
    stride = max(n // 2048, 1)
    return jnp.quantile(jnp.abs(flat[::stride]), q)


_sampled_quantile_batch = jax.jit(jax.vmap(_sampled_quantile,
                                           in_axes=(0, None)))


@jax.jit
def _mask_count(flat, thr):
    keep = jnp.abs(flat) >= thr
    return jnp.where(keep, flat, 0), jnp.sum(keep)


_mask_count_batch = jax.jit(jax.vmap(_mask_count))


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def flatten_tree(tree):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat


def unflatten_like(flat, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    idx = 0
    for l in leaves:
        n = int(np.prod(l.shape))   # leaves may be ShapeDtypeStructs
        out.append(flat[idx:idx + n].reshape(tuple(l.shape)).astype(l.dtype))
        idx += n
    return jax.tree_util.tree_unflatten(treedef, out)


def stack_trees(trees):
    """List of pytrees -> one pytree with a leading client axis."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def flatten_stacked(tree):
    """Pytree with leading client axis K -> (K, N) flat stack.

    Row i equals ``flatten_tree`` of client i's tree (same leaf order), so
    the stack can feed the aggregation kernels directly with no per-tree
    flatten/stack round trip.
    """
    leaves = jax.tree.leaves(tree)
    K = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(K, -1).astype(jnp.float32) for l in leaves], axis=1)


def unflatten_stacked(flat, template_tree):
    """(K, N) flat stack -> pytree with leading client axis K.

    ``template_tree`` is a single (unstacked) tree giving leaf shapes/dtypes.
    """
    leaves, treedef = jax.tree_util.tree_flatten(template_tree)
    K = flat.shape[0]
    out = []
    idx = 0
    for l in leaves:
        n = int(np.prod(l.shape))   # leaves may be ShapeDtypeStructs
        out.append(flat[:, idx:idx + n].reshape((K,) + tuple(l.shape))
                   .astype(l.dtype))
        idx += n
    return jax.tree_util.tree_unflatten(treedef, out)


WIRE_FORMATS = ("csr", "csr_q", "dense_masked")
CSR_FORMATS = ("csr", "csr_q")
Q_DTYPES = ("int8", "fp16")
Q_BLOCK = 512             # csr_q in-block offset range (csr_compact's
                          # stage-1 block size): offsets are int16 in
                          # [0, Q_BLOCK) and the block-count table has
                          # ceil(n / Q_BLOCK) entries per row
# the fault injector's malformed-payload menu: every class of corruption
# the wire validator must catch. Each kind maps to one specific mutilation
# in SparseComm.malform_stats and every kind raises WireIntegrityError
# under every CSR-family wire format.
MALFORM_KINDS = ("row_ptr", "oob_index", "nan_value", "bad_scale",
                 "arity", "truncated", "dtype")


class WireIntegrityError(ValueError):
    """An incoming upload failed wire validation (malformed row_ptr,
    out-of-bounds index, non-finite value/scale, wrong arity/dtype/shape,
    truncated buffer). The payload must be quarantined — never decoded,
    never aggregated, never booked."""
CAP_FACTOR = 2.5          # payload capacity slack over the target keep_frac:
                          # near-tied delta magnitudes (e.g. sign-like early
                          # Adam steps) push the kept fraction past the
                          # quantile target, and capping real mass costs
                          # accuracy — 2.5x covers the measured worst case
                          # while keeping the buffer well under dense
RESIDUAL_FRAC = 0.25      # EF residual store: top fraction of N kept by
                          # magnitude -> 2N bytes/client vs 4N dense


class SparseComm:
    """Stateful comm channel with deferred ACO bookkeeping.

    ``threshold`` modes:
      float   — absolute magnitude threshold (the paper's L1+threshold form)
      "p<frac>" — keep the top <frac> fraction by magnitude (quantile mode);
                  default p0.2 reproduces the paper's ~0.49 ACO exactly
                  (payload = nnz * 8 bytes vs dense 4 bytes/param).

    ``wire_format`` / ``capacity`` / ``cap_factor`` / ``residual_frac``:
    see the module docstring. ``capacity=None`` derives the per-row payload
    capacity from the keep fraction; an explicit int pins it.

    Error-feedback residuals and forced restarts: a residual is delta mass
    accumulated against the base the client held when it last uploaded.
    When the scheduler force-restarts a deprecated client (version gap >
    tau) its in-flight trajectory is discarded and it starts over from the
    new global model — the trainer therefore RESETS that client's residual
    to zero at the forced restart (pinned in tests/test_error_feedback.py).
    Re-offering the stale residual against a base the client no longer has
    would inject drift that EF exists to prevent; fresh base, fresh
    residual. (Residuals of ordinary participants persist across rounds as
    usual — that carry-over is the whole point of EF.)

    Byte counters: ``dense_bytes`` is host-computable (4 bytes/param/message)
    and kept as a plain int; payload bytes need the on-device nnz count, so
    each message appends one ``(stored_count, value_bytes_per_element,
    index_bytes_per_element)`` entry to ``_pending_payload`` — the count is
    a device scalar, the per-element widths are the format's — and the
    ``aco`` / ``payload_bytes`` / ``wire_breakdown`` readers fold the list
    into per-component host totals with a single stacked transfer. The
    host-computable framing accumulates separately as plain ints: row_ptr
    (``4 * (rows + 1)`` per CSR batch), per-row scales and block-count
    tables (csr_q), and dense payloads (disabled channel, full-model
    resyncs) in ``_dense_payload_host``.
    """

    def __init__(self, threshold="p0.2", *, use_kernel=True, enabled=True,
                 wire_format="csr", capacity=None, cap_factor=CAP_FACTOR,
                 residual_frac=RESIDUAL_FRAC, q_dtype="int8", layout=None):
        if wire_format not in WIRE_FORMATS:
            raise ValueError(f"wire_format must be one of {WIRE_FORMATS}, "
                             f"got {wire_format!r}")
        if q_dtype not in Q_DTYPES:
            raise ValueError(f"q_dtype must be one of {Q_DTYPES}, "
                             f"got {q_dtype!r}")
        self.threshold = threshold
        self.layout = layout            # core.param_layout.ParamLayout | None
        self._chunk_plan = None
        self.use_kernel = use_kernel
        self.enabled = enabled
        self.wire_format = wire_format
        self.capacity = capacity
        self.cap_factor = cap_factor
        self.residual_frac = residual_frac
        self.q_dtype = q_dtype
        self._values_host = 0.0         # materialized per-component bytes
        self._indices_host = 0.0
        self._dense_payload_host = 0.0  # dense payloads (disabled / resync)
        self._pending_payload = []      # (count_dev, val_bytes, idx_bytes)
        self._batch_cores = {}          # residual? -> jitted encode pipeline
        self._csr_cores = {}            # residual? -> jitted CSR pipeline
        self.dense_bytes = 0
        self.row_ptr_bytes = 0
        self.scales_bytes = 0           # csr_q per-row scale framing
        self.block_table_bytes = 0      # csr_q per-row block-count framing
        self.messages = 0

    @property
    def _payload_host(self):
        """Materialized variable-size payload bytes (back-compat view of
        the per-component ledger; excludes host-tracked framing, exactly as
        before the split)."""
        return self._values_host + self._indices_host + \
            self._dense_payload_host

    def elem_bytes(self):
        """(value_bytes, index_bytes) per stored element on this channel's
        wire format: f32+int32 for ``csr``/``dense_masked``, int8+int16
        offset for ``csr_q`` (fp16 fallback: 2+2)."""
        if self.wire_format == "csr_q":
            return (2, 2) if self.q_dtype == "fp16" else (1, 2)
        return (4, 4)

    def row_overhead_bytes(self, n):
        """Host-computable per-row framing beyond the shared row_ptr:
        (scale_bytes, block_table_bytes) for one n-param csr_q row — the
        f32 absmax scale (omitted in fp16 mode, where scales are the
        constant 1) and the int16 per-block count table. Zero under f32
        CSR, whose indices are self-describing absolute columns.

        Under a chunked layout the per-row framing is per CHUNK per row —
        one absmax scale and one block table per chunk — so a full-model
        (n == layout.n) csr_q message books the chunked wire truthfully."""
        if self.wire_format != "csr_q":
            return 0, 0
        scale = 0 if self.q_dtype == "fp16" else 4
        chunks = self._layout_chunks(n)
        if chunks > 1:
            table = sum(2 * max((nc + 511) // 512, 1)
                        for nc in self.layout.sizes)
            return scale * chunks, table
        return scale, 2 * max((n + 511) // 512, 1)

    def _layout_chunks(self, n):
        """Number of layout chunks an n-param message spans: the layout
        applies only to full-model messages (n == layout.n); everything
        else (server data messages, sub-vector payloads) stays flat."""
        if self.layout is not None and n == self.layout.n:
            return self.layout.num_chunks
        return 1

    # -- threshold ---------------------------------------------------------
    def _quantile_frac(self):
        if isinstance(self.threshold, str) and self.threshold.startswith("p"):
            return float(self.threshold[1:])
        return None

    def _abs_threshold(self, flat):
        """Device scalar threshold for one flat delta (no host sync)."""
        frac = self._quantile_frac()
        if frac is not None:
            return _sampled_quantile(flat, 1.0 - frac)
        return jnp.float32(self.threshold)

    def _abs_threshold_batch(self, flat_stack):
        """(K,) device thresholds, one vmapped quantile per client."""
        frac = self._quantile_frac()
        if frac is not None:
            return _sampled_quantile_batch(flat_stack, 1.0 - frac)
        K = flat_stack.shape[0]
        return jnp.full((K,), self.threshold, jnp.float32)

    # -- CSR wire format ---------------------------------------------------
    def payload_capacity(self, n):
        """Static per-row payload capacity for an n-param message."""
        if self.capacity is not None:
            return max(1, min(int(self.capacity), n))
        frac = self._quantile_frac()
        if frac is None:                 # absolute threshold: nnz unbounded
            return n
        return max(1, min(n, int(math.ceil(self.cap_factor * frac * n))))

    def residual_capacity(self, n):
        """Static per-row capacity of the EF residual store."""
        return max(1, min(n, int(math.ceil(self.residual_frac * n))))

    def _row_thresholds(self, delta):
        """(K,) per-row thresholds for this channel's mode."""
        frac = self._quantile_frac()
        if frac is not None:
            return local_quantile_thresholds(delta, frac)
        return jnp.full((delta.shape[0],), float(self.threshold),
                        jnp.float32)

    def _compact(self, delta, thr, cap):
        """delta (K, n) x (K,) thresholds -> the (values, indices, nnz)
        wire payload at capacity ``cap``."""
        if self.use_kernel:
            return kops.csr_compact(delta, thr, cap)
        return kref.csr_compact2d_ref(delta, thr, cap)

    def _quantize(self, vals, idx, stored, n):
        """Packed f32 payload -> the csr_q quadruple
        (qvals, offsets, block_counts, scales)."""
        if self.use_kernel:
            return kops.csr_quantize(vals, idx, stored, n,
                                     q_dtype=self.q_dtype)
        qvals, scales = kref.csr_quantize2d_ref(vals, stored,
                                                q_dtype=self.q_dtype)
        offs, counts = kref.csr_pack_indices_ref(idx, stored, n)
        return qvals, offs, counts, scales

    def csr_core(self, with_residual=False):
        """Jitted CSR-family encode pipeline on (K, n) flat stacks, built
        once per (instance, residual?). Per-row ops only, so calling it
        inside a ``shard_map`` over the client axis matches the unsharded
        result.

        Without residual: (new, base) -> (payload, stored, decoded) where
        ``payload`` is the wire tuple — ``(values, indices)`` under f32
        ``csr``, ``(qvals, offsets, block_counts, scales)`` under
        ``csr_q`` — ``stored = min(nnz, cap)`` is the on-wire count and
        ``decoded`` is the server-side reconstruction of the payload
        (under ``csr_q`` the DEQUANTIZED decode: what the server actually
        recovers, rounding loss included).

        With residual: (new, base, residual) -> (payload, stored, decoded,
        (rvalues, rindices, rstored), residual_dense) — the new residual is
        ``delta + residual - decoded`` (sub-threshold mass, capacity
        overflow, AND — under csr_q — quantization rounding error all spill
        back), truncated to the residual store's capacity;
        ``residual_dense`` is its dense expansion for engines that keep
        dense per-client rows. The residual store is local client state and
        never crosses the wire, so it stays f32 CSR under every format.
        The caller owns accounting (``account_batch_csr`` with the stored
        counts).
        """
        key = bool(with_residual)
        core = self._csr_cores.get(key)
        if core is not None:
            return core
        compact, row_thr = self._compact, self._row_thresholds
        pay_cap, res_cap = self.payload_capacity, self.residual_capacity
        residual_frac = self.residual_frac
        quantized, q_dtype = self.wire_format == "csr_q", self.q_dtype
        quantize = self._quantize
        # dense reconstructions use the scatter-free capped-mask twin of the
        # compact->decode round-trip (identical output; XLA:CPU scatters are
        # serial, and on paths that only read the stored counts the
        # compaction sort dead-code-eliminates entirely). Under csr_q the
        # twin extends through quantization: the absmax over the packed
        # prefix equals the absmax over the capped-mask rows, so the
        # elementwise quantize->dequantize round-trip of the dense rows is
        # bit-identical to scattering the dequantized payload.
        capped = kref.csr_capped_mask_ref

        def encode_payload(delta, n):
            thr = row_thr(delta)
            vals, idx, _ = compact(delta, thr, pay_cap(n))
            dense, stored = capped(delta, thr, pay_cap(n))
            if not quantized:
                return (vals, idx), stored, dense
            qvals, offs, counts, scales = quantize(vals, idx, stored, n)
            decoded = kref.quantize_dense_ref(dense, scales, q_dtype=q_dtype)
            return (qvals, offs, counts, scales), stored, decoded

        if with_residual:
            @jax.jit
            def core(new_flat, base_flat, residual_flat):
                n = new_flat.shape[1]
                delta = new_flat - base_flat + residual_flat
                payload, stored, decoded = encode_payload(delta, n)
                res = delta - decoded   # sub-threshold + overflow (+ csr_q
                                        # quantization error: EF absorption)
                r_thr = local_quantile_thresholds(res, residual_frac)
                rvals, ridx, _ = compact(res, r_thr, res_cap(n))
                res_dense, rstored = capped(res, r_thr, res_cap(n))
                return (payload, stored, decoded,
                        (rvals, ridx, rstored), res_dense)
        else:
            @jax.jit
            def core(new_flat, base_flat):
                n = new_flat.shape[1]
                delta = new_flat - base_flat
                return encode_payload(delta, n)

        self._csr_cores[key] = core
        return core

    # -- chunked parameter axis (core.param_layout) ------------------------
    def set_layout(self, layout):
        """Attach a :class:`~repro.core.param_layout.ParamLayout`. Accounting
        for full-model messages (row_ptr / scales / block tables) switches to
        the per-chunk framing; a ``None`` or single-chunk layout keeps the
        flat books bit-identical."""
        self.layout = layout
        self._chunk_plan = None

    def chunk_plan(self):
        """Per-chunk encode plan derived from the layout: a list of dicts
        ``{s, e, nc, keep, cap, rcap, roff}`` where ``keep`` is the chunk's
        keep-fraction override (``None`` -> channel default), ``cap`` the
        payload capacity at the chunk's width, and ``[roff, roff + rcap)``
        the chunk's segment of the concatenated EF residual page."""
        if self._chunk_plan is not None:
            return self._chunk_plan
        if self.layout is None:
            raise ValueError("chunk_plan() requires a layout (set_layout)")
        default_frac = self._quantile_frac()
        plan, roff = [], 0
        for c in range(self.layout.num_chunks):
            s, e = self.layout.bounds[c]
            nc = e - s
            keep = self.layout.keep_frac[c]
            frac = keep if keep is not None else default_frac
            if self.capacity is not None:
                cap = max(1, min(int(self.capacity), nc))
            elif frac is None:          # absolute threshold: nnz unbounded
                cap = nc
            else:
                cap = max(1, min(nc, int(math.ceil(self.cap_factor
                                                   * frac * nc))))
            rfrac = self.layout.residual_frac[c]
            rfrac = rfrac if rfrac is not None else self.residual_frac
            rcap = max(1, min(nc, int(math.ceil(rfrac * nc))))
            plan.append({"s": s, "e": e, "nc": nc, "keep": keep, "cap": cap,
                         "rfrac": rfrac, "rcap": rcap, "roff": roff})
            roff += rcap
        self._chunk_plan = plan
        return plan

    def residual_capacity_total(self):
        """Total per-client EF residual capacity under the layout: the sum
        of the per-chunk capacities (== the width of the concatenated
        residual page a chunked engine stores per client)."""
        return sum(p["rcap"] for p in self.chunk_plan())

    def _chunk_thresholds(self, delta_c, keep):
        """(K,) per-row thresholds for one chunk: the chunk's keep-fraction
        override when present, else the channel's mode."""
        if keep is not None:
            return local_quantile_thresholds(delta_c, keep)
        return self._row_thresholds(delta_c)

    def _chunk_encode_one(self, delta_c, plan_c):
        """One chunk of the CSR-family encode: (K, nc) delta -> (payload
        wire tuple, stored (K,), decoded (K, nc)). Always the jnp reference
        oracles — per-chunk widths are ragged and the caller fuses this into
        its own jit, where the elementwise/cumsum oracles compile to the
        same fused loops the Pallas grids hand-build at flat N."""
        nc, cap = plan_c["nc"], plan_c["cap"]
        thr = self._chunk_thresholds(delta_c, plan_c["keep"])
        vals, idx, _ = kref.csr_compact2d_ref(delta_c, thr, cap)
        dense, stored = kref.csr_capped_mask_ref(delta_c, thr, cap)
        if self.wire_format != "csr_q":
            return (vals, idx), stored, dense
        qvals, scales = kref.csr_quantize2d_ref(vals, stored,
                                                q_dtype=self.q_dtype)
        offs, counts = kref.csr_pack_indices_ref(idx, stored, nc)
        decoded = kref.quantize_dense_ref(dense, scales, q_dtype=self.q_dtype)
        return (qvals, offs, counts, scales), stored, decoded

    def chunk_encode_body(self, with_residual=False):
        """Per-chunk encode pipeline over (K, N) stacks — the chunked twin
        of :meth:`csr_core`. NOT jitted: the caller fuses the returned
        callable into its own jitted round stage, and the chunk loop is
        unrolled there so XLA's buffer liveness keeps at most one chunk's
        delta/decode temporaries (O(K * max_chunk)) live at a time while
        ``new``/``base`` stay the already-materialized parameter stacks.

        Without residual: ``fn(new, base) -> (payloads, stored, decoded)``
        — per-chunk lists of wire tuples, (K,) stored counts and (K, nc)
        dequantized decodes; payload column indices are chunk-local.

        With residual: ``fn(new, base, rvals, ridx) -> (payloads, stored,
        decoded, (rvals', ridx'))`` where the EF residual pages are
        (K, rcap_total) concatenations of per-chunk CSR segments holding
        GLOBAL column indices (segment c spans ``[roff_c, roff_c+rcap_c)``
        and only carries columns from chunk c; zero-value pads sit at the
        chunk start, so the per-chunk scatter decode is exact).

        ``base`` may be a (K, N) array or a callable ``(s, e) -> (K, e-s)``
        — the versioned engines pass a ring-gather closure so no (K, N)
        base copy is ever materialized.
        """
        plan = self.chunk_plan()

        def base_cols(base, s, e):
            return base(s, e) if callable(base) else base[:, s:e]

        if not with_residual:
            def body(new, base):
                payloads, stored, decoded = [], [], []
                for p in plan:
                    s, e = p["s"], p["e"]
                    delta_c = new[:, s:e] - base_cols(base, s, e)
                    pay, st, dec = self._chunk_encode_one(delta_c, p)
                    payloads.append(pay)
                    stored.append(st)
                    decoded.append(dec)
                return payloads, stored, decoded
            return body

        def body(new, base, rvals, ridx):
            payloads, stored, decoded = [], [], []
            new_rv, new_ri = [], []
            for p in plan:
                s, e, nc = p["s"], p["e"], p["nc"]
                roff, rcap = p["roff"], p["rcap"]
                rv_c = rvals[:, roff:roff + rcap]
                # global -> chunk-local columns; zero-value pads sit at
                # global index 0 and clip to local 0, scattering nothing
                ri_c = jnp.clip(ridx[:, roff:roff + rcap] - s, 0, nc - 1)
                res_c = kref.csr_decode_ref(rv_c, ri_c, nc)
                delta_c = new[:, s:e] - base_cols(base, s, e) + res_c
                pay, st, dec = self._chunk_encode_one(delta_c, p)
                res_new = delta_c - dec     # sub-threshold + overflow
                                            # (+ csr_q rounding error)
                r_thr = local_quantile_thresholds(res_new, p["rfrac"])
                rv, ri, _ = kref.csr_compact2d_ref(res_new, r_thr, rcap)
                payloads.append(pay)
                stored.append(st)
                decoded.append(dec)
                new_rv.append(rv)
                new_ri.append(ri + s)       # store GLOBAL columns
            return payloads, stored, decoded, \
                (jnp.concatenate(new_rv, axis=1),
                 jnp.concatenate(new_ri, axis=1))
        return body

    def chunk_advance_body(self):
        """Chunked twin of the versioned ring's advance encode: one flat
        (n,) transition ``new - prev`` encoded chunk-by-chunk, returning
        ``(recon, chain_payload)`` where ``recon`` is the full decoded
        reconstruction and ``chain_payload`` matches the flat chain-entry
        contract — ``(vals, idx, stored)`` under csr with the per-chunk
        payloads concatenated and indices made global, ``(qvals, offs,
        counts, scales, stored)`` under csr_q with a (num_chunks,) scale
        vector (one absmax per chunk: exactly the bytes the chunked wire
        ships, so the chain's byte ledger stays truthful). Chain entries
        are accounting-only (virtual clients never decode them), so the
        concatenation is never unpacked."""
        plan = self.chunk_plan()
        quantized = self.wire_format == "csr_q"

        def body(new_flat, prev_flat):
            recon, parts, stored_sum = [], [], 0
            for p in plan:
                s, e = p["s"], p["e"]
                delta_c = (new_flat[s:e] - prev_flat[s:e])[None]
                pay, st, dec = self._chunk_encode_one(delta_c, p)
                recon.append(prev_flat[s:e] + dec[0])
                stored_sum = stored_sum + st[0]
                if quantized:
                    parts.append((pay[0][0], pay[1][0], pay[2][0],
                                  pay[3][0]))
                else:
                    # global columns; value-0 pads land at the chunk start
                    parts.append((pay[0][0], pay[1][0] + s))
            cat = tuple(jnp.concatenate([p[i] for p in parts])
                        for i in range(2))
            if quantized:
                scales = jnp.stack([p[3] for p in parts])
                counts = jnp.concatenate([p[2] for p in parts])
                chain = cat + (counts, scales, stored_sum)
            else:
                chain = cat + (stored_sum,)
            return jnp.concatenate(recon), chain
        return body

    def account_batch_csr(self, stored_nnz, params_per_message, n_messages):
        """Record an n_messages-row CSR-family batch whose on-device stored
        counts are ``stored_nnz``: one value + one index per stored element
        at this format's widths, one shared row_ptr per batch, plus — under
        csr_q — the per-row scale and block-count framing. No host sync."""
        if not self.enabled:
            self.account_batch(stored_nnz, params_per_message, n_messages)
            return
        vb, ib = self.elem_bytes()
        self._pending_payload.append((jnp.sum(stored_nnz), vb, ib))
        self.row_ptr_bytes += \
            4 * (n_messages + 1) * self._layout_chunks(params_per_message)
        sb, bb = self.row_overhead_bytes(params_per_message)
        self.scales_bytes += sb * n_messages
        self.block_table_bytes += bb * n_messages
        self.dense_bytes += params_per_message * n_messages * 4
        self.messages += n_messages

    def account_payload(self, stored_total_dev, params_per_message,
                        n_messages, *, row_ptr_rows=0):
        """Record ``n_messages`` CSR-family messages whose total STORED
        ELEMENT COUNT was already reduced on device (one scalar). Used by
        the versioned base store's broadcast accounting, which folds its
        chain-suffix count sum into a single jitted reduction instead of
        handing nnz vectors back for re-summing (every eager op on the
        replicated stage outputs costs a multi-device dispatch). The
        element count is converted to component bytes at this channel's
        per-element widths; ``row_ptr_rows`` adds the CSR framing —
        ``4 * (rows + 1)`` row_ptr plus the csr_q per-row scale/block-table
        overhead. No host sync."""
        vb, ib = self.elem_bytes()
        self._pending_payload.append((stored_total_dev, vb, ib))
        if row_ptr_rows:
            self.row_ptr_bytes += \
                4 * (row_ptr_rows + 1) * self._layout_chunks(params_per_message)
            sb, bb = self.row_overhead_bytes(params_per_message)
            self.scales_bytes += sb * row_ptr_rows
            self.block_table_bytes += bb * row_ptr_rows
        self.dense_bytes += params_per_message * n_messages * 4
        self.messages += n_messages

    def account_dense_payload(self, total_bytes, params_per_message,
                              n_messages):
        """Record ``n_messages`` plain dense messages (full-model resync
        unicasts): host-computable, booked straight into the dense payload
        component."""
        self._dense_payload_host += float(total_bytes)
        self.dense_bytes += params_per_message * n_messages * 4
        self.messages += n_messages

    def wire_breakdown(self):
        """Cumulative bytes-on-wire by component. Materializes pending
        device scalars (one transfer). Every pending entry carries its
        format's per-element widths, so the split is truthful under every
        format: f32 CSR stores one fp32 value + one int32 index per element
        (even split), csr_q stores int8 + int16 (values a third of
        indices-plus-table), and messages on a disabled channel are plain
        dense vectors reported as ``dense_payload_bytes`` instead of being
        mislabelled as CSR components. The csr_q per-row block-count tables
        are index-decoding side information and report under
        ``indices_bytes``; the per-row absmax scales get their own
        ``scales_bytes`` component. Components always sum to
        ``payload_bytes``. The nested ``layout`` entry reports the chunked
        parameter axis the framing was booked under (``num_chunks == 1``
        on an unchunked channel)."""
        self._materialize()
        if self.layout is not None:
            layout = self.layout.describe()
        else:
            layout = {"num_chunks": 1}
        return {"values_bytes": self._values_host,
                "indices_bytes": self._indices_host + self.block_table_bytes,
                "scales_bytes": float(self.scales_bytes),
                "row_ptr_bytes": float(self.row_ptr_bytes),
                "dense_payload_bytes": self._dense_payload_host,
                "payload_bytes": self.payload_bytes,
                "layout": layout}

    def deliver(self, stats):
        """Book a payload's bytes-on-wire at DELIVERY time.

        ``stats`` is the dict returned by :meth:`encode` /
        :meth:`encode_batch` called with ``deliver=False``: encoding is the
        client-side act of building the payload; *this* is the upload
        actually arriving at the server. A lost upload's stats are simply
        never delivered, so its bytes never inflate ACO — the ledger counts
        what crossed the wire, not what was produced. Booking is
        byte-identical to the inline (``deliver=True``) accounting of the
        path that produced ``stats``. No host sync.
        """
        K, n = stats["rows"], stats["total"]
        if not self.enabled:
            self._dense_payload_host += K * n * 4
            self.dense_bytes += K * n * 4
            self.messages += K
        elif "values" in stats:               # CSR family (csr / csr_q)
            self.account_batch_csr(stats["nnz"], n, K)
        else:                                         # dense_masked
            self._account(jnp.sum(stats["nnz"]), n * K, K)

    def _csr_stats(self, payload, stored, n, *, rows):
        """Delivery stats for a CSR-family payload tuple. ``rows=None``
        marks a 1-row stack from the single-message path (entries are
        unstacked before packing the dict). The f32 ``csr`` contract —
        ``values``/``indices`` carry the payload arrays — is unchanged;
        ``csr_q`` reuses those keys for the quantized values / int16
        offsets and adds ``blocks``/``scales``."""
        if rows is None:
            payload = tuple(p[0] for p in payload)
            stored, rows = stored[0], 1
        stats = {"nnz": stored, "total": n, "rows": rows,
                 "values": payload[0], "indices": payload[1]}
        if self.wire_format == "csr_q":
            stats["blocks"], stats["scales"] = payload[2], payload[3]
        return stats

    # -- wire integrity ----------------------------------------------------
    def validate_payload(self, stats):
        """Wire-integrity gauntlet for an incoming payload, applied at the
        trust boundary (an upload arriving from an untrusted device) BEFORE
        decode or accounting. Raises :class:`WireIntegrityError` on any
        malformation; returns ``stats`` unchanged on success.

        Checks, in order: arity (exactly the keys this channel's wire
        format ships — 2 payload arrays for ``csr``, 4 for ``csr_q``),
        buffer shapes (no truncation: every array spans ``rows`` x the
        shared capacity), dtypes (integer indices/counts, the format's
        value width), the implied row_ptr (per-row stored counts
        non-negative and within capacity, i.e. the CSR row_ptr is monotone
        and in-capacity), index bounds (every stored column inside
        ``[0, total)``; csr_q offsets inside their decode block), csr_q
        block-count tables consistent with the stored counts, and finite
        values/scales (a NaN or inf would poison the aggregate through a
        single scatter-add).

        Host-syncing by design: validation runs only on untrusted
        boundary payloads (quarantine candidates, tests), never inside the
        engines' jitted round bodies.
        """
        def fail(msg):
            raise WireIntegrityError(f"malformed upload: {msg}")

        if not isinstance(stats, dict):
            fail(f"payload is {type(stats).__name__}, not a stats mapping")
        for k in ("nnz", "total", "rows"):
            if k not in stats:
                fail(f"missing framing field {k!r}")
        try:
            rows, n = int(stats["rows"]), int(stats["total"])
        except (TypeError, ValueError):
            fail("non-integer rows/total framing")
        if rows < 1 or n < 1:
            fail(f"non-positive framing (rows={rows}, total={n})")

        quantized = self.wire_format == "csr_q"
        payload_keys = {"values", "indices"} | \
            ({"blocks", "scales"} if quantized else set())
        got = {k for k in ("values", "indices", "blocks", "scales")
               if k in stats}
        if got != payload_keys:
            if not self.enabled or self.wire_format not in CSR_FORMATS:
                # dense-family message: only the count field to check
                stored = np.asarray(stats["nnz"], np.float64).reshape(-1)
                if not np.isfinite(stored).all() or (stored < 0).any() \
                        or (stored > n).any():
                    fail("dense message count outside [0, total]")
                return stats
            fail(f"wrong payload arity for {self.wire_format!r}: expected "
                 f"fields {sorted(payload_keys)}, got {sorted(got)}")

        vals = np.asarray(stats["values"])
        idx = np.asarray(stats["indices"])
        stored = np.asarray(stats["nnz"])
        if stored.size != rows:
            fail(f"stored-count vector has {stored.size} entries for "
                 f"{rows} rows")
        if not np.issubdtype(stored.dtype, np.integer):
            fail(f"stored counts must be integers, got {stored.dtype}")
        stored = stored.reshape(-1).astype(np.int64)
        if vals.size == 0 or vals.size % rows or idx.size % rows:
            fail("truncated payload buffer: array size not divisible by "
                 "the row count")
        cap = vals.size // rows
        if idx.size != rows * cap:
            fail(f"truncated payload buffer: values span {cap} "
                 f"columns/row, indices {idx.size // rows}")
        vals = vals.reshape(rows, cap)
        idx = idx.reshape(rows, cap)
        if not np.issubdtype(idx.dtype, np.integer):
            fail(f"indices must be integers, got {idx.dtype}")
        want_val = (np.int8 if self.q_dtype == "int8" else np.float16) \
            if quantized else np.float32
        if vals.dtype != np.dtype(want_val):
            fail(f"values dtype {vals.dtype} != {np.dtype(want_val)} for "
                 f"wire format {self.wire_format!r}")
        # the implied row_ptr (concat([0], cumsum(stored))) must be
        # monotone and land inside the buffer: stored in [0, cap]
        if (stored < 0).any() or (stored > cap).any():
            fail(f"row_ptr not monotone in-capacity: stored counts must "
                 f"lie in [0, {cap}], got "
                 f"[{int(stored.min())}, {int(stored.max())}]")
        live = np.arange(cap)[None, :] < stored[:, None]
        bound = Q_BLOCK if quantized else n
        if ((idx < 0) & live).any() or ((idx >= bound) & live).any():
            fail(f"column {'offset' if quantized else 'index'} out of "
                 f"bounds [0, {bound})")
        if not np.isfinite(vals[live].astype(np.float64)).all():
            fail("non-finite payload value")
        if quantized:
            blocks = np.asarray(stats["blocks"])
            scales = np.asarray(stats["scales"])
            if not np.issubdtype(blocks.dtype, np.integer):
                fail(f"block-count table must be integers, got "
                     f"{blocks.dtype}")
            nblocks = blocks.size // rows if blocks.size % rows == 0 else -1
            if nblocks < 1:
                fail("truncated block-count table")
            blocks = blocks.reshape(rows, nblocks).astype(np.int64)
            if (blocks < 0).any():
                fail("negative block count")
            if (blocks.sum(axis=1) != stored).any():
                fail("block-count table inconsistent with stored counts")
            scales = scales.astype(np.float64).reshape(-1)
            if not np.isfinite(scales).all():
                fail("non-finite quantization scale")
        return stats

    def malform_stats(self, stats, kind):
        """Return a copy of ``stats`` corrupted in one specific way —
        ``kind`` from :data:`MALFORM_KINDS`. This is the fault injector's
        bit-flip/truncation menu: the trainer uses it to materialize a
        ``corrupt``-fated upload's damage deterministically, and the
        quarantine tests sweep it to pin that every class is caught.
        Every kind raises :class:`WireIntegrityError` under every
        CSR-family wire format (pinned by tests/test_wire_integrity.py)."""
        if kind not in MALFORM_KINDS:
            raise ValueError(f"kind must be one of {MALFORM_KINDS}, "
                             f"got {kind!r}")
        out = dict(stats)
        quantized = self.wire_format == "csr_q"
        if kind == "row_ptr":           # negative count: row_ptr decreases
            stored = np.asarray(out["nnz"]).reshape(-1).copy()
            stored[0] = -1
            out["nnz"] = stored
        elif kind == "oob_index":       # column past the model / block edge
            idx = np.array(out["indices"]).reshape(
                int(out["rows"]), -1).copy()
            idx[0, 0] = Q_BLOCK if quantized else int(out["total"])
            stored = np.asarray(out["nnz"]).reshape(-1).copy()
            stored[0] = max(int(stored[0]), 1)   # the bad column is live
            out["indices"], out["nnz"] = idx, stored
        elif kind == "nan_value":       # f32: NaN value; csr_q: inf scale
            if quantized:
                scales = np.array(out["scales"], np.float32).reshape(-1)
                scales[0] = np.inf
                out["scales"] = scales
            else:
                vals = np.array(out["values"], np.float32).reshape(
                    int(out["rows"]), -1)
                vals[0, 0] = np.nan
                out["values"] = vals
                stored = np.asarray(out["nnz"]).reshape(-1).copy()
                stored[0] = max(int(stored[0]), 1)
                out["nnz"] = stored
        elif kind == "bad_scale":       # csr_q: NaN scale; csr: spurious
            if quantized:               # scale field (wrong arity)
                scales = np.array(out["scales"], np.float32).reshape(-1)
                scales[0] = np.nan
                out["scales"] = scales
            else:
                out["scales"] = np.ones(int(out["rows"]), np.float32)
        elif kind == "arity":           # a payload array went missing
            del out["indices"]
        elif kind == "truncated":       # values buffer cut short in flight
            vals = np.asarray(out["values"]).reshape(int(out["rows"]), -1)
            out["values"] = vals[:, :-1] if vals.shape[1] > 1 \
                else np.zeros((int(out["rows"]), 0), vals.dtype)
        elif kind == "dtype":           # indices arrive as floats
            out["indices"] = np.asarray(out["indices"], np.float32)
        return out

    # -- checkpoint / restore ----------------------------------------------
    def ledger_state(self, *, defer=False):
        """Snapshot the cumulative byte ledgers as plain host numbers.
        Materializes the pending device scalars first — value-neutral,
        because the fold is order-preserving and future messages append
        after it either way.

        ``defer=True`` (the checkpoint writer path) does not block on
        in-flight device work: the pending fold is captured as
        :class:`fleet_ckpt.Lazy` thunks over references taken now and
        resolved on the writer thread — same entries, same order, same
        float64 host arithmetic as the eager fold — while the LIVE
        ledger's pending list is left untouched."""
        if not defer:
            self._materialize()
            values = float(self._values_host)
            indices = float(self._indices_host)
        else:
            from repro.core import fleet_ckpt
            vb, ib = float(self._values_host), float(self._indices_host)
            pend = list(self._pending_payload)

            def _fold(base, col):
                # per-element np.asarray: the writer thread must never
                # LAUNCH device programs (a jnp.stack dispatched
                # concurrently with the training thread's multi-device
                # round can interleave collective rendezvous and deadlock
                # XLA:CPU) — transfers only. Counts are exact integers, so
                # the float64 fold matches the eager stack path exactly.
                out = base
                for entry in pend:
                    out += float(np.asarray(entry[0])) * entry[col]
                return out

            values = fleet_ckpt.Lazy(lambda: _fold(vb, 1))
            indices = fleet_ckpt.Lazy(lambda: _fold(ib, 2))
        return {"values_host": values,
                "indices_host": indices,
                "dense_payload_host": float(self._dense_payload_host),
                "dense_bytes": int(self.dense_bytes),
                "row_ptr_bytes": int(self.row_ptr_bytes),
                "scales_bytes": int(self.scales_bytes),
                "block_table_bytes": int(self.block_table_bytes),
                "messages": int(self.messages)}

    def load_ledger_state(self, d):
        """Restore :meth:`ledger_state` output (drops any pending
        unmaterialized entries — the checkpoint is the truth)."""
        self._pending_payload = []
        self._values_host = float(d["values_host"])
        self._indices_host = float(d["indices_host"])
        self._dense_payload_host = float(d["dense_payload_host"])
        self.dense_bytes = int(d["dense_bytes"])
        self.row_ptr_bytes = int(d["row_ptr_bytes"])
        self.scales_bytes = int(d["scales_bytes"])
        self.block_table_bytes = int(d["block_table_bytes"])
        self.messages = int(d["messages"])

    # -- single-message path (reference implementation) --------------------
    def encode(self, new_params, base_params, residual=None, *,
               deliver=True):
        """Returns (sparse_delta_tree, stats[, residual']). ACO accounted
        at once when ``deliver=True``; with ``deliver=False`` nothing is
        booked until the caller passes ``stats`` to :meth:`deliver` (or
        drops them — a lost upload).

        ``residual``: error-feedback state (beyond-paper): the masked-out
        part of every previous delta is carried forward and re-offered next
        round, so sparsification error does not accumulate into model drift
        (Karimireddy et al.-style EF). Pass a zero tree to enable; the new
        residual is returned alongside.

        ``stats["nnz"]`` is a device scalar (reads sync on demand). Under
        the CSR wire format it is the on-wire (stored) count, the returned
        sparse tree is the server-side decode of the actual payload, and —
        with EF — the returned residual is the capacity-truncated store
        (sub-threshold mass plus any capacity overflow).
        """
        delta = tree_sub(new_params, base_params)
        if residual is not None:
            delta = tree_add(delta, residual)
        flat = flatten_tree(delta)
        n = flat.shape[0]
        if not self.enabled:
            stats = {"nnz": n, "total": n, "rows": 1}
            if deliver:
                self.deliver(stats)
            out = (delta, stats)
            return out + (jax.tree.map(jnp.zeros_like, delta),) \
                if residual is not None else out
        if self.wire_format in CSR_FORMATS:
            # the flat delta (incl. residual) goes through the shared CSR
            # core as a 1-row stack — identical math to the batched path
            zero = jnp.zeros_like(flat)[None]
            if residual is not None:
                payload, stored, decoded, _, res_dense = self.csr_core(
                    True)(flat[None], zero, zero)
            else:
                payload, stored, decoded = self.csr_core(False)(
                    flat[None], zero)
            sparse_tree = unflatten_like(decoded[0], delta)
            stats = self._csr_stats(payload, stored, n, rows=None)
            if deliver:
                self.deliver(stats)
            if residual is not None:
                return sparse_tree, stats, unflatten_like(res_dense[0], delta)
            return sparse_tree, stats
        thr = self._abs_threshold(flat)
        if self.use_kernel:
            masked, nnz_blocks = kops.sparse_delta(flat, thr)
            nnz = jnp.sum(nnz_blocks)
        else:
            masked, nnz = _mask_count(flat, thr)
        stats = {"nnz": nnz, "total": n, "rows": 1}
        if deliver:
            self.deliver(stats)
        sparse_tree = unflatten_like(masked, delta)
        if residual is not None:
            new_residual = unflatten_like(flat - masked, delta)
            return sparse_tree, stats, new_residual
        return sparse_tree, stats

    def encode_paged(self, new_params, base_params, res_vals, res_idx, *,
                     deliver=True):
        """Single-message CSR-family encode against a PAGED residual: the
        client's error-feedback state arrives as one (rcap,) CSR page
        (values, indices) from ``core.client_store.PagedClientStore`` and
        the truncated new residual returns as a page for the writeback
        queue. Returns ``(sparse_delta_tree, stats, (rvals', ridx'))``.

        Bit-identical to :meth:`encode` with the page's dense expansion as
        ``residual``: the page scatter-add decodes to exactly the dense
        residual row the resident layout stores (the capped-mask round-trip
        contract), and adding it to the flat delta is elementwise — the
        same values :meth:`encode` produces by adding trees leaf-wise and
        flattening. Only valid under the CSR wire formats (the paged dense
        layout goes through :meth:`encode` unchanged)."""
        delta = tree_sub(new_params, base_params)
        flat = flatten_tree(delta)
        n = flat.shape[0]
        flat = flat + kops.csr_decode(res_vals[None], res_idx[None], n)[0]
        zero = jnp.zeros_like(flat)[None]
        payload, stored, decoded, res_payload, _ = self.csr_core(True)(
            flat[None], zero, zero)
        stats = self._csr_stats(payload, stored, n, rows=None)
        if deliver:
            self.deliver(stats)
        return unflatten_like(decoded[0], delta), stats, \
            (res_payload[0][0], res_payload[1][0])

    # -- batched path ------------------------------------------------------
    def _batch_core(self, with_residual):
        """Jitted (delta -> threshold -> mask -> count) pipeline, built once
        per (instance, residual?) so the whole encode is ONE dispatch."""
        key = bool(with_residual)
        core = self._batch_cores.get(key)
        if core is not None:
            return core
        frac = self._quantile_frac()
        threshold = None if frac is not None else float(self.threshold)
        use_kernel = self.use_kernel

        def encode(delta):
            if use_kernel and frac is not None:
                # fused per-shard form: local per-row quantile thresholds
                # feed the 2D-grid kernel directly (one dispatch; safe
                # under shard_map because thresholds are per-row)
                masked, nnz_blocks, _ = kops.sparse_delta_topfrac(delta, frac)
                return masked, jnp.sum(nnz_blocks, axis=1)
            if frac is not None:
                thr = _sampled_quantile_batch(delta, 1.0 - frac)
            else:
                thr = jnp.full((delta.shape[0],), threshold, jnp.float32)
            if use_kernel:
                masked, nnz_blocks = kops.sparse_delta_batch(delta, thr)
                nnz = jnp.sum(nnz_blocks, axis=1)
            else:
                masked, nnz = _mask_count_batch(delta, thr)
            return masked, nnz

        if with_residual:
            @jax.jit
            def core(new_flat, base_flat, residual_flat):
                delta = new_flat - base_flat + residual_flat
                masked, nnz = encode(delta)
                return masked, nnz, delta - masked
        else:
            @jax.jit
            def core(new_flat, base_flat):
                return encode(new_flat - base_flat)

        self._batch_cores[key] = core
        return core

    def encode_batch(self, new_flat, base_flat, residual_flat=None, *,
                     deliver=True):
        """Encode K client deltas at once from (K, N) flat stacks.
        ``deliver=False`` skips the inline accounting — the caller books
        the returned ``stats`` via :meth:`deliver` when (and only if) the
        payload actually arrives.

        Returns (masked (K, N), stats[, residual' (K, N)]) where
        ``stats["nnz"]`` is the per-client (K,) device nnz vector. Per-client
        quantile thresholds, masking and nnz counting all stay on device —
        zero host syncs — in one jitted call wrapping the 2D-grid kernel
        (``use_kernel``) or the vmapped jnp oracle.

        Under the CSR wire format the first return value is the decoded
        payload (== the masked stack unless a row overflowed its capacity),
        ``stats["nnz"]`` is the stored count, and ``stats`` also carries the
        actual (values, indices) payload arrays.
        """
        K, n = new_flat.shape
        if not self.enabled:
            delta = new_flat - base_flat
            if residual_flat is not None:
                delta = delta + residual_flat
            stats = {"nnz": jnp.full((K,), n), "total": n, "rows": K}
            if deliver:
                self.deliver(stats)
            out = (delta, stats)
            return out + (jnp.zeros_like(delta),) \
                if residual_flat is not None else out
        if self.wire_format in CSR_FORMATS:
            if residual_flat is not None:
                payload, stored, decoded, _, res_dense = self.csr_core(
                    True)(new_flat, base_flat, residual_flat)
            else:
                payload, stored, decoded = self.csr_core(False)(
                    new_flat, base_flat)
            stats = self._csr_stats(payload, stored, n, rows=K)
            if deliver:
                self.deliver(stats)
            if residual_flat is not None:
                return decoded, stats, res_dense
            return decoded, stats
        if residual_flat is not None:
            masked, nnz, new_residual = self._batch_core(True)(
                new_flat, base_flat, residual_flat)
        else:
            masked, nnz = self._batch_core(False)(new_flat, base_flat)
        stats = {"nnz": nnz, "total": n, "rows": K}
        if deliver:
            self.deliver(stats)
        if residual_flat is not None:
            return masked, stats, new_residual
        return masked, stats

    def apply(self, base_params, sparse_delta_tree):
        return tree_add(base_params, sparse_delta_tree)

    def batch_core(self, with_residual=False):
        """The pure jitted encode pipeline (delta -> thresholds -> mask ->
        per-client nnz), for callers that fuse it into a larger jitted round
        stage. The caller owns accounting: pass the returned nnz to
        ``account_batch``.

        Shard-safe: thresholds are per-row statistics, so calling this
        inside a ``shard_map`` over the client axis (each shard encoding
        its local (K/D, N) rows) produces exactly the unsharded result —
        the sharded fleet engine relies on this.
        """
        return self._batch_core(with_residual)

    def account_batch(self, nnz, params_per_message, n_messages):
        """Record n_messages messages of params_per_message params whose
        combined on-device nnz vector is ``nnz`` (ignored when sparsification
        is disabled — then every message is dense). No host sync."""
        if not self.enabled:
            self._dense_payload_host += n_messages * params_per_message * 4
            self.dense_bytes += n_messages * params_per_message * 4
            self.messages += n_messages
            return
        self._account(jnp.sum(nnz), params_per_message * n_messages,
                      n_messages)

    # -- deferred accounting -----------------------------------------------
    def _account(self, nnz_dev, total_params, n_messages):
        # dense_masked: fp32 value + int32 index per survivor
        self._pending_payload.append((nnz_dev, 4, 4))
        self.dense_bytes += total_params * 4
        self.messages += n_messages

    def _materialize(self):
        if self._pending_payload:
            counts = np.asarray(jnp.stack(
                [c for c, _, _ in self._pending_payload]), np.float64)
            for cnt, (_, vb, ib) in zip(counts, self._pending_payload):
                self._values_host += float(cnt) * vb
                self._indices_host += float(cnt) * ib
            self._pending_payload = []

    @property
    def payload_bytes(self) -> float:
        self._materialize()
        return self._values_host + self._indices_host + \
            self._dense_payload_host + self.row_ptr_bytes + \
            self.scales_bytes + self.block_table_bytes

    @property
    def aco(self) -> float:
        return self.payload_bytes / self.dense_bytes if self.dense_bytes \
            else 0.0
