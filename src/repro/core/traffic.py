"""Per-client availability and traffic model for fault-injected fleets.

The paper's regime (§IV-C) is heterogeneous, resource-constrained IoT
clients — crashes, flaky uplinks and churn are the norm, not the exception.
This module is the *fault source* the :class:`~repro.core.scheduler.
SemiAsyncScheduler` draws from to turn its happy-path timing simulation into
a faulted one:

* **heavy-tailed compute** — each run's latency is scaled by a lognormal
  multiplier with unit mean (``tail_sigma``), so a minority of runs straggle
  far past the paper's linear latency fit while the fleet mean is preserved;
* **crash-mid-run** (``crash_rate``) — the run dies at a uniform point of
  its duration and its upload never exists; the client reboots immediately
  and retries *from its persisted base version* (its on-disk model survives
  the crash), so repeated crashing shows up as emergent staleness and —
  past ``tau`` — as a forced restart, never as scripted behaviour;
* **upload loss** (``upload_loss``) — the run finishes but the payload is
  dropped in transit.  The client, like every uploader, then listens for
  the next global broadcast: it becomes a distribution target of the next
  round but NOT an aggregation participant, and its upload bytes are never
  booked (bytes-on-wire counts deliveries, not encodes);
* **payload corruption** (``corrupt_prob``) — the run finishes and its
  payload *arrives*, but the bytes are malformed (bit flips, truncation).
  The server's wire-integrity validation rejects it and the upload is
  quarantined through the lost-upload path: never aggregated, never
  booked, the client's EF residual retired, the round's ``quarantined``
  count reported in fleet health;
* **leave/rejoin churn** (``mean_online`` / ``mean_offline``, exponential
  session lengths) — a leaving client cancels its in-flight run and its
  server-side error-feedback residual is retired like a forced restart's; a
  rejoining client waits for the next round boundary, where it is either
  served the chain-delta suffix (parked version still inside the
  staleness window) or an explicit full-model resync payload (version
  evicted from the ring — accounted on the wire, not silently free);
* **late joins** (``late_join_frac``) — that fraction of the fleet starts
  the simulation offline and joins mid-run through the same rejoin path.

All draws come from a *dedicated* RNG owned by the scheduler (never the
latency-jitter stream), so enabling faults cannot perturb the fault-free
schedule, and the same ``(profile, seed)`` pair produces the bit-identical
fault trace however many times — and under whichever engine — it is
replayed.  Draw counts per decision are fixed (three uniforms per run fate,
one per duration) so traces stay aligned across profiles that share a seed.
A profile with ``corrupt_prob > 0`` draws one extra uniform per fate — the
corruption axis shifts the stream ONLY when it is enabled, so every
pre-existing trace is untouched.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# crash/loss probabilities are capped below 1: a fleet whose every run
# crashes can never produce an upload, and next_round would (correctly but
# unhelpfully) spin through its event guard — refuse the profile up front
MAX_FAULT_RATE = 0.95


@dataclass(frozen=True)
class TrafficModel:
    """A fault profile. All rates are per-run probabilities; durations are
    seconds of simulated fleet time (the scheduler's clock)."""

    crash_rate: float = 0.0        # P(run crashes mid-run; upload never born)
    upload_loss: float = 0.0       # P(finished run's upload lost in transit)
    corrupt_prob: float = 0.0      # P(delivered payload arrives malformed
                                   # and is quarantined by the server's
                                   # wire-integrity validation)
    tail_sigma: float = 0.0        # lognormal sigma of the latency
                                   # multiplier (0 = deterministic); the
                                   # multiplier has unit MEAN, so the
                                   # paper's latency fit stays the average
    mean_online: float = math.inf  # mean online session before leaving
                                   # (inf = clients never leave)
    mean_offline: float = 600.0    # mean offline stretch before rejoining
    late_join_frac: float = 0.0    # fraction of the fleet starting offline
                                   # (joins mid-simulation via rejoin)

    def __post_init__(self):
        for name in ("crash_rate", "upload_loss", "corrupt_prob"):
            v = getattr(self, name)
            if not 0.0 <= v <= MAX_FAULT_RATE:
                raise ValueError(f"{name} must be in [0, {MAX_FAULT_RATE}] "
                                 f"(got {v}): rates near 1 starve the fleet "
                                 f"of uploads entirely")
        if not 0.0 <= self.late_join_frac <= 1.0:
            raise ValueError(f"late_join_frac must be in [0, 1], got "
                             f"{self.late_join_frac}")
        if self.tail_sigma < 0:
            raise ValueError(f"tail_sigma must be >= 0, got "
                             f"{self.tail_sigma}")
        if self.mean_online <= 0 or self.mean_offline <= 0:
            raise ValueError("mean_online / mean_offline must be positive")

    @property
    def churns(self) -> bool:
        return math.isfinite(self.mean_online)

    # -- draws (rng is the scheduler's dedicated fault stream) --------------
    def latency_multiplier(self, rng) -> float:
        """Unit-mean lognormal straggler factor (heavy right tail)."""
        if self.tail_sigma <= 0:
            return 1.0
        s = self.tail_sigma
        return float(rng.lognormal(-0.5 * s * s, s))

    def run_fate(self, rng):
        """Sample one run's fate at start time.

        Returns ``(fate, frac)`` with fate in {"ok", "crash", "lost",
        "corrupt"} and ``frac`` the fraction of the run's duration survived
        before a crash (meaningful only when fate == "crash").  Always
        exactly three uniforms — plus one more iff ``corrupt_prob > 0`` —
        so the stream stays aligned across outcomes, and enabling the
        corruption axis is the only thing that can shift it.
        """
        u_crash, u_loss, frac = rng.random(), rng.random(), rng.random()
        u_corrupt = rng.random() if self.corrupt_prob > 0 else 1.0
        if u_crash < self.crash_rate:
            return "crash", float(frac)
        if u_loss < self.upload_loss:
            return "lost", float(frac)
        if u_corrupt < self.corrupt_prob:
            return "corrupt", float(frac)
        return "ok", float(frac)

    def online_duration(self, rng) -> float:
        if not self.churns:
            return math.inf
        return float(rng.exponential(self.mean_online))

    def offline_duration(self, rng) -> float:
        return float(rng.exponential(self.mean_offline))

    def initial_offline(self, rng, M):
        """Sorted client ids starting the simulation offline (late joins)."""
        if self.late_join_frac <= 0:
            return []
        mask = rng.random(M) < self.late_join_frac
        return [int(i) for i in mask.nonzero()[0]]


# The reference churn profile: the fault regime the acceptance scenario,
# the chaos suite's cross-engine runs and the ``bench_fleet --faults``
# cells all share. Crash and loss rates follow the ISSUE's acceptance
# numbers; the churn means are chosen relative to the paper's measured
# 166–317 s client latencies so a typical client stays online for a
# handful of rounds and an exponential-tail offline stretch occasionally
# outlives the tau+2 ring window (exercising the full-model resync path).
REFERENCE_CHURN = TrafficModel(
    crash_rate=0.10,
    upload_loss=0.05,
    tail_sigma=0.5,
    mean_online=2500.0,
    mean_offline=500.0,
    late_join_frac=0.1,
)
