"""Paper metrics (§V-C): class-weighted Accuracy / Precision / Recall / F1 /
FPR, computed per class one-vs-rest and weighted by class support — plus the
fleet-health summary of a faulted run's round logs.
"""
from __future__ import annotations

import numpy as np


def weighted_metrics(y_true, y_pred, num_classes):
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    n = len(y_true)
    support = np.bincount(y_true, minlength=num_classes).astype(np.float64)
    w = support / max(n, 1)

    prec = np.zeros(num_classes)
    rec = np.zeros(num_classes)
    f1 = np.zeros(num_classes)
    fpr = np.zeros(num_classes)
    for c in range(num_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        tn = n - tp - fp - fn
        prec[c] = tp / max(tp + fp, 1)
        rec[c] = tp / max(tp + fn, 1)
        f1[c] = 2 * tp / max(2 * tp + fn + fp, 1)
        fpr[c] = fp / max(fp + tn, 1)

    return {
        "accuracy": float(np.mean(y_true == y_pred)),
        "precision": float(np.sum(w * prec)),
        "recall": float(np.sum(w * rec)),
        "f1": float(np.sum(w * f1)),
        "fpr": float(np.sum(w * fpr)),
    }


def fleet_health(logs):
    """Summarize a run's RoundLogs into the fault/degradation metrics the
    chaos suite and ``bench_fleet --faults`` report.

    ``mean_quorum_frac`` is the round-efficiency headline: delivered
    uploads over the participation target k, averaged over rounds — 1.0 on
    the happy path, degrading as crashes/losses/churn eat into quorums
    (``target_k`` is 0 on pre-fault logs; those rounds count as full).
    Every entry derives purely from the scheduler's fault trace, so it is
    bit-identical across engines replaying the same trace.
    """
    rounds = len(logs)
    fracs = [l.quorum / l.target_k for l in logs if l.target_k]
    return {
        "rounds": rounds,
        "degraded_rounds": sum(1 for l in logs if l.degraded),
        "deadline_hits": sum(1 for l in logs if l.deadline_hit),
        "mean_quorum_frac": float(np.mean(fracs)) if fracs else 1.0,
        "crashes": sum(l.crashes for l in logs),
        "lost_uploads": sum(len(l.lost) for l in logs),
        "quarantined": sum(len(l.corrupted) for l in logs),
        "departures": sum(len(l.departed) for l in logs),
        "rejoins": sum(len(l.rejoined) for l in logs),
        "resyncs": sum(len(l.resynced) for l in logs),
        "forced_restarts": sum(len(l.forced) for l in logs),
    }
