"""Paper metrics (§V-C): class-weighted Accuracy / Precision / Recall / F1 /
FPR, computed per class one-vs-rest and weighted by class support.
"""
from __future__ import annotations

import numpy as np


def weighted_metrics(y_true, y_pred, num_classes):
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    n = len(y_true)
    support = np.bincount(y_true, minlength=num_classes).astype(np.float64)
    w = support / max(n, 1)

    prec = np.zeros(num_classes)
    rec = np.zeros(num_classes)
    f1 = np.zeros(num_classes)
    fpr = np.zeros(num_classes)
    acc_c = np.zeros(num_classes)
    for c in range(num_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        tn = n - tp - fp - fn
        prec[c] = tp / max(tp + fp, 1)
        rec[c] = tp / max(tp + fn, 1)
        f1[c] = 2 * tp / max(2 * tp + fn + fp, 1)
        fpr[c] = fp / max(fp + tn, 1)
        acc_c[c] = (tp + tn) / max(n, 1)

    return {
        "accuracy": float(np.mean(y_true == y_pred)),
        "precision": float(np.sum(w * prec)),
        "recall": float(np.sum(w * rec)),
        "f1": float(np.sum(w * f1)),
        "fpr": float(np.sum(w * fpr)),
    }
