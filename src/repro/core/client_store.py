"""Participant-paged client state (``client_store="paged"``).

The resident layout keeps the server's per-client state — error-feedback
residual rows, per-client versions, participation counters — as (M, ...)
DEVICE arrays, so device memory grows with the fleet even though a round
only ever touches its K participants. :class:`PagedClientStore` moves that
state to host memory (numpy; optionally a memory-mapped file set) and
serves each round a device-side *window* holding only the participants'
pages:

* round prologue — :meth:`gather_csr` / :meth:`gather_dense` fancy-index
  the participants' pages out of the host store and place them on device
  (after draining any queued writes, see below);
* round epilogue — :meth:`scatter_csr` / :meth:`scatter_dense` queue the
  round's updated pages, and :meth:`retire` queues the fault-driven page
  invalidations (tau-forced restarts, lost uploads, churn departures,
  rejoiners) that the resident engines apply as device-wide scatters.

Writes are DEFERRED: scatter/retire only enqueue, and the queue drains at
the next gather (or an explicit :meth:`flush`). The device->host
materialization of a round's residual pages therefore overlaps the host
work that follows the round — scheduler bookkeeping, the next boundary's
event processing — instead of blocking the epilogue; this is the
double-buffering that keeps paged rounds within the regression gate's
0.9x-of-resident throughput budget. Queue order is preserved, so a
retirement queued after the same round's scatter zeroes the page exactly
like the resident scatter-then-reset sequence.

Numerics are bit-identical to the resident layout: a CSR page decodes
(scatter-add, ``kernels.ops.csr_decode``) to exactly the dense residual
row the resident engines store — the capped-mask/compact round-trip
contract pinned in tests/test_kernels.py — and gathers of retired or
never-written pages return exact zeros, the same rows a resident reset
writes. The engine parity matrix pins paged vs resident runs equal.

Per-client *versions* stay owned by ``VersionedBaseStore`` (they are
already host-side numpy there); this store only adopts references via
:meth:`adopt_versions` so :meth:`host_bytes` reports the full host-side
per-client footprint. Participation/staleness counters (``part_count``,
``last_round``) live here and are updated from the shared round epilogue.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

LAYOUTS = ("csr", "dense", "none")


class PagedClientStore:
    """Host-resident per-client pages + a device gather/scatter window.

    ``layout`` selects the residual page shape: ``"csr"`` keeps the
    capacity-bounded (M, rcap) values/indices pair the CSR wire formats
    use, ``"dense"`` keeps dense (M, n) rows (the ``dense_masked``
    reference format's residual), ``"none"`` allocates no residual pages
    at all (error feedback off — the store still carries the counters and
    byte accounting).

    ``paged_dir``: when set, the residual page arrays are ``.npy``
    memory-maps under that directory instead of anonymous RAM — the
    explicit spill-to-disk option for fleets whose nominal page store
    exceeds memory. Plain ``np.zeros`` is already lazily committed on
    Linux (untouched pages cost nothing), so the memmap is only needed
    when *touched* pages outgrow RAM.
    """

    def __init__(self, M, n, rcap, *, layout="csr", paged_dir=None):
        if layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, "
                             f"got {layout!r}")
        self.M = int(M)
        self.n = int(n)
        self.rcap = int(rcap)
        self.layout = layout
        self.paged_dir = os.fspath(paged_dir) if paged_dir is not None \
            else None
        if layout == "csr":
            self.res_vals = self._alloc("res_vals", (M, rcap), np.float32)
            self.res_idx = self._alloc("res_idx", (M, rcap), np.int32)
            self._pages = (self.res_vals, self.res_idx)
        elif layout == "dense":
            self.res_rows = self._alloc("res_rows", (M, n), np.float32)
            self._pages = (self.res_rows,)
        else:
            self._pages = ()
        # a page is readable only while valid; retire() clears the bit and
        # the page reads as zero — no O(M) host write, no stale mass
        self.valid = np.zeros(M, bool)
        self.part_count = np.zeros(M, np.int64)
        self.last_round = np.full(M, -1, np.int64)
        self._queue = []            # ordered ("scatter", ids, arrays) /
                                    # ("retire", ids) ops, drained on gather
        self._window_bytes = 0      # device bytes of the last gather window
        self._versions = ()         # adopted VersionedBaseStore arrays

    def _alloc(self, name, shape, dtype):
        if self.paged_dir is None:
            return np.zeros(shape, dtype)
        os.makedirs(self.paged_dir, exist_ok=True)
        path = os.path.join(self.paged_dir, f"{name}.npy")
        return np.lib.format.open_memmap(path, mode="w+", shape=shape,
                                         dtype=dtype)

    def adopt_versions(self, *arrays):
        """Reference the host-side per-client version arrays owned by the
        VersionedBaseStore (``client_version``, ``detached``) so
        :meth:`host_bytes` reports the complete per-client footprint."""
        self._versions = arrays

    # -- deferred write queue ----------------------------------------------
    def scatter_csr(self, ids, vals, idx):
        """Queue the round's updated (K, rcap) CSR residual pages for
        ``ids``. Device arrays are kept as-is — the host copy happens at
        the next :meth:`flush` / gather, overlapping the post-round host
        work (the double buffer)."""
        if len(ids):
            self._queue.append(("scatter", np.asarray(ids, np.int64),
                                (vals, idx)))

    def scatter_dense(self, ids, rows):
        """Queue updated dense (K, n) residual rows for ``ids``."""
        if len(ids):
            self._queue.append(("scatter", np.asarray(ids, np.int64),
                                (rows,)))

    def retire(self, ids):
        """Queue page invalidation for ``ids`` (forced restarts, lost
        uploads, departures, rejoiners): their residual mass was
        accumulated against a base they no longer hold. Ordered after any
        same-round scatter, exactly like the resident engines' sequence."""
        if len(ids):
            self._queue.append(("retire", np.asarray(ids, np.int64)))

    def flush(self):
        """Drain the write queue into the host pages, in order."""
        for op in self._queue:
            if op[0] == "scatter":
                _, rows, arrays = op
                for dst, src in zip(self._pages, arrays):
                    dst[rows] = np.asarray(src)
                self.valid[rows] = True
            else:
                self.valid[op[1]] = False
        self._queue = []

    # -- gather windows -----------------------------------------------------
    def _gather(self, ids):
        self.flush()
        rows = np.asarray(ids, np.int64)
        bad = ~self.valid[rows]
        out = []
        for page in self._pages:
            win = page[rows]               # fancy index -> fresh ndarray
            if bad.any():
                win[bad] = 0
            out.append(jnp.asarray(win))
        self._window_bytes = int(sum(w.nbytes for w in out))
        return tuple(out)

    def gather_csr(self, ids):
        """(len(ids), rcap) device (values, indices) window. Invalid
        (retired / never-written) pages read as zeros — ``csr_decode`` of
        an all-zero page is the zero residual row."""
        return self._gather(ids)

    def gather_dense(self, ids):
        """(len(ids), n) device dense-residual window."""
        return self._gather(ids)[0]

    # -- counters -----------------------------------------------------------
    def record_participation(self, ids, round_no):
        """Bump participation counters for this round's uploaders;
        ``last_round`` makes per-client staleness ``round - last_round`` a
        host-side lookup, like the versions the base store keeps."""
        if len(ids):
            rows = np.asarray(ids, np.int64)
            self.part_count[rows] += 1
            self.last_round[rows] = int(round_no)

    # -- checkpoint / restore ----------------------------------------------
    def state_dict(self):
        """Snapshot the paged state: the write queue is drained first (and
        memmap pages are fsynced to their backing files), then only the
        VALID pages are captured, sparsely — invalid pages read as zero by
        contract, so a fleet where most clients never participated
        checkpoints at O(touched), not O(M * page)."""
        self.flush()
        for p in self._pages:
            if isinstance(p, np.memmap):
                p.flush()
        ids = np.nonzero(self.valid)[0].astype(np.int64)
        return {"M": self.M, "n": self.n, "rcap": self.rcap,
                "layout": self.layout,
                "ids": ids,
                "pages": [np.ascontiguousarray(p[ids])
                          for p in self._pages],
                "part_count": self.part_count.copy(),
                "last_round": self.last_round.copy()}

    def load_state_dict(self, d):
        """Restore :meth:`state_dict` output onto a store of the same
        geometry. Pages not in the snapshot are invalidated (they read as
        zero); their stale bytes are never touched."""
        for k in ("M", "n", "rcap"):
            if int(d[k]) != getattr(self, k):
                raise ValueError(f"paged-store state has {k}={d[k]}, this "
                                 f"store has {k}={getattr(self, k)}")
        if d["layout"] != self.layout:
            raise ValueError(f"paged-store state has layout "
                             f"{d['layout']!r}, this store has "
                             f"{self.layout!r}")
        self._queue = []
        self.valid[:] = False
        ids = np.asarray(d["ids"], np.int64)
        for dst, src in zip(self._pages, d["pages"]):
            dst[ids] = np.asarray(src).reshape((ids.size,) + dst.shape[1:])
        self.valid[ids] = True
        self.part_count[:] = np.asarray(d["part_count"],
                                        np.int64).reshape(self.M)
        self.last_round[:] = np.asarray(d["last_round"],
                                        np.int64).reshape(self.M)
        self._window_bytes = 0

    # -- inspection ---------------------------------------------------------
    def residual_row(self, i):
        """Dense (n,) host residual of client ``i`` (test/debug accessor;
        drains the queue first). Matches the resident layout: retired or
        never-written pages are exact zeros, CSR pages scatter-add decode
        like ``kernels.ops.csr_decode``."""
        self.flush()
        out = np.zeros(self.n, np.float32)
        if self.layout == "none" or not self.valid[i]:
            return out
        if self.layout == "dense":
            out[:] = self.res_rows[i]
            return out
        np.add.at(out, self.res_idx[i], self.res_vals[i])
        return out

    # -- byte accounting ----------------------------------------------------
    def device_window_bytes(self):
        """Device-resident bytes of per-client state right now: the last
        gather window plus any queued (not yet materialized) writeback
        pages — O(K * page), flat in M."""
        pending = sum(int(a.nbytes) for op in self._queue if op[0] ==
                      "scatter" for a in op[2])
        return self._window_bytes + pending

    def host_bytes(self):
        """Nominal host bytes of the full per-client store: residual pages
        + validity bits + counters + the adopted version arrays. Nominal —
        ``np.zeros`` pages are lazily committed and memmap pages live on
        disk, so resident set is typically far smaller."""
        total = sum(int(p.nbytes) for p in self._pages)
        total += int(self.valid.nbytes + self.part_count.nbytes
                     + self.last_round.nbytes)
        total += sum(int(np.asarray(v).nbytes) for v in self._versions)
        return total

    def residual_store_bytes(self):
        """Nominal bytes of the residual pages alone (0 when EF is off) —
        the paged counterpart of the resident residual-store report."""
        return sum(int(p.nbytes) for p in self._pages)
