"""The paper's weighting functions (§IV-D, §IV-E).

* dynamic supervised-learning weight f(r): alpha=1/2 -> beta=1/(C*M+1)
* staleness functions g(s): constant / polynomial / hinge / exponential
* round-weight functions h(r): constant / logarithmic / polynomial /
  exponential smoothing / exponential
* adaptive learning rate eta_i = lambda / (M * f_i) with round-weighted
  participation frequency (Eq. 11-12).
"""
from __future__ import annotations

import math

import numpy as np

E = math.e


# --- dynamic supervised weight f(r) (§IV-D1) -------------------------------
def supervised_weight(r, *, C, M, alpha=0.5, kappa=10.0, mode="adaptive"):
    """Monotone decay from alpha to beta = 1/(C*M+1).

    The paper fixes the endpoints and monotonicity but not the curve; we use
    exponential decay with time constant ``kappa`` rounds (recorded choice).
    ``mode``: adaptive | fixed_alpha | fixed_beta (for Table XI ablation).
    """
    beta = 1.0 / (C * M + 1.0)
    if mode == "fixed_alpha":
        return alpha
    if mode == "fixed_beta":
        return beta
    return beta + (alpha - beta) * math.exp(-r / kappa)


# --- staleness functions g(s) (§V-D1) ---------------------------------------
def staleness_fn(name, a=None, b=0):
    name = name.lower()
    if name == "constant":
        return lambda s: 1.0
    if name == "polynomial":
        aa = 0.5 if a is None else a
        return lambda s: float((s + 1.0) ** (-aa))
    if name == "hinge":
        # FedAsync-style hinge: flat at 1 until s = b, then the polynomial
        # decay RESTARTS at the hinge point — 1 / (a * (s - b) + 1), which
        # is continuous at s = b for any b (the former s + b form jumped
        # from 1 to 1/(2ab+1) there whenever b > 0)
        aa = 1.0 if a is None else a
        return lambda s: 1.0 if s <= b else 1.0 / (aa * (s - b) + 1.0)
    if name == "exponential":
        aa = E / 2 if a is None else a
        return lambda s: float(aa ** (-s))
    raise ValueError(name)


# --- round-weight functions h(r) (§V-D2) ------------------------------------
def round_weight_fn(name, a=None):
    name = name.lower()
    if name == "constant":
        return lambda r: 1.0
    if name == "logarithmic":
        return lambda r: math.log1p(r)
    if name == "polynomial":
        aa = 0.5 if a is None else a
        return lambda r: (1.0 + r) ** aa
    if name == "exponential_smoothing":
        aa = 0.1 if a is None else a
        return lambda r: (1.0 + aa) ** r
    if name == "exponential":
        aa = E / 2 if a is None else a
        return lambda r: aa ** r
    raise ValueError(name)


# --- adaptive learning rate (Eq. 11-12) --------------------------------------
def adaptive_learning_rates(participation, *, base_lr, round_weight="constant",
                            clip=(0.2, 5.0), adaptive=True):
    """participation: (R_so_far, M) 0/1 matrix of global-update participation.

    f_i = sum_r h(r) * part[r, i] / sum_j sum_r h(r) * part[r, j]
    eta_i = lambda / (M * f_i), clipped to clip * lambda.
    """
    participation = np.asarray(participation, dtype=np.float64)
    M = participation.shape[1]
    if not adaptive or participation.size == 0:
        return np.full(M, base_lr)
    h = round_weight_fn(round_weight)
    w = np.array([h(r) for r in range(participation.shape[0])])
    scores = (w[:, None] * participation).sum(axis=0)
    total = scores.sum()
    if total <= 0:
        return np.full(M, base_lr)
    f = scores / total
    with np.errstate(divide="ignore"):
        eta = np.where(f > 0, base_lr / (M * np.maximum(f, 1e-12)),
                       base_lr * clip[1])
    return np.clip(eta, base_lr * clip[0], base_lr * clip[1])
