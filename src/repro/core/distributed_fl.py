"""FedS3A on the production mesh: the paper's federated round as a single
pjit-compiled step over ANY model-zoo architecture.

Mapping (DESIGN.md §3): the M federated clients are the ``data`` mesh axis.
One fl_train_step executes:

  1. every client runs local SGD steps on its own shard of the batch
     (vmap over the client axis — params broadcast, batch/client-state sharded),
  2. client deltas are sparsified (paper §IV-F, top-k magnitude mask),
  3. the staleness/size-weighted, participation-masked aggregation (Eq. 9/10)
     happens as ONE weighted reduction over the client axis — XLA lowers it to
     the reduce-scatter/all-reduce this paper's parameter-server would be,
  4. the server's supervised delta joins with the dynamic weight f(r).

Because participation/staleness arrive as DATA (mask + staleness vectors),
the same compiled step serves every semi-async round — no recompilation as
the arriving subset changes (TPU-friendly static shapes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.training.steps import lm_loss


def sgd_local_steps(cfg: ModelConfig, *, lr, num_steps=1, window=None,
                    impl="flash", moe_impl="einsum"):
    """Local training a client runs per round: ``num_steps`` SGD steps over
    its microbatches. batch leaves: (num_steps, b, ...)."""

    def local(params, batch):
        def one(p, mb):
            g = jax.grad(lambda pp: lm_loss(cfg, pp, mb, window=window,
                                            impl=impl, moe_impl=moe_impl))(p)
            p = jax.tree.map(
                lambda x, gg: (x.astype(jnp.float32) -
                               lr * gg.astype(jnp.float32)).astype(x.dtype),
                p, g)
            return p, None

        params, _ = jax.lax.scan(one, params, batch)
        return params

    return local


def _topk_mask(delta_flat_leaf, keep_frac):
    """Per-leaf magnitude threshold approximating the (1-keep_frac) quantile.

    NOT jnp.quantile: an exact quantile sorts the flattened leaf, and on
    model-sharded deltas GSPMD implements that as a full all-gather per leaf
    per client — 85 GB/round/device measured, i.e. the paper's own
    sparsification step costing more wire than it saves (EXPERIMENTS §Perf C).
    Instead the threshold comes from mean/std of |delta| (scalar reductions,
    bytes-free): for ~gaussian deltas thr = mu + z(keep_frac) * sigma.
    """
    a = jnp.abs(delta_flat_leaf.astype(jnp.float32))
    mu = jnp.mean(a)
    sigma = jnp.std(a)
    # z such that P(|x| > thr) ~ keep_frac for half-normal |x|
    z = {0.5: 0.0, 0.25: 0.72, 0.2: 0.9, 0.1: 1.4}.get(round(keep_frac, 2), 0.9)
    thr = mu + z * sigma
    return jnp.where(a >= thr, delta_flat_leaf, 0)


def make_fl_train_step(cfg: ModelConfig, *, num_clients, lr=1e-3,
                       local_steps=1, keep_frac=0.0, window=None,
                       impl="flash", moe_impl="einsum", f_weight=0.25,
                       staleness_decay=1.359, reduce_dtype="bfloat16"):
    """Returns fl_step(global_params, batch, mask, staleness, sizes)
       -> (new_global_params, aggregate_weight_sum).

    batch leaves: (M, local_steps, b, ...) — client-major, sharded over the
    ``data`` axis. mask/staleness/sizes: (M,).
    The server's supervised step is the M=0 slot by convention (its mask is
    folded into f_weight outside for the paper-CNN runs; for the LM demo all
    slots are clients).
    """
    local = sgd_local_steps(cfg, lr=lr, num_steps=local_steps, window=window,
                            impl=impl, moe_impl=moe_impl)

    def fl_step(global_params, batch, mask, staleness, sizes):
        # 1. local training, batched over the client axis
        new_params = jax.vmap(local, in_axes=(None, 0))(global_params, batch)

        # 2. deltas (+ optional paper sparsification)
        deltas = jax.tree.map(
            lambda n, g: n - g[None].astype(n.dtype), new_params, global_params)
        if keep_frac:
            deltas = jax.tree.map(
                jax.vmap(partial(_topk_mask, keep_frac=keep_frac)), deltas)

        # 3. Eq. 9 weights: |D_i|/|D_c| * g(r - r_i) * participation
        g_s = staleness_decay ** (-staleness.astype(jnp.float32))
        w = mask.astype(jnp.float32) * sizes.astype(jnp.float32) * g_s
        w = w / jnp.maximum(jnp.sum(w), 1e-12)

        # 4. ONE weighted reduction over the client axis (the FL collective).
        # The reduction runs in ``reduce_dtype`` (bf16 default): the all-reduce
        # payload is the partial-sum dtype, so this halves the FL wire bytes —
        # the beyond-paper counterpart of the paper's sparse-diff idea
        # (EXPERIMENTS.md §Perf case C).
        rdt = jnp.dtype(reduce_dtype)

        def reduce_leaf(d, g):
            upd = jnp.einsum("m,m...->...", w.astype(rdt), d.astype(rdt))
            return (g.astype(jnp.float32) +
                    (1.0 - f_weight) * upd.astype(jnp.float32)).astype(g.dtype)

        new_global = jax.tree.map(reduce_leaf, deltas, global_params)
        return new_global, jnp.sum(w)

    return fl_step


def fl_input_specs(cfg: ModelConfig, *, num_clients, local_steps, batch_per_step,
                   seq_len):
    """ShapeDtypeStructs for the FL dry-run."""
    M = num_clients
    b = {"tokens": jax.ShapeDtypeStruct((M, local_steps, batch_per_step, seq_len),
                                        jnp.int32)}
    if cfg.num_vision_patches:
        b["patches"] = jax.ShapeDtypeStruct(
            (M, local_steps, batch_per_step, cfg.num_vision_patches, cfg.d_model),
            jnp.bfloat16)
    if cfg.is_encoder_decoder:
        b["frames"] = jax.ShapeDtypeStruct(
            (M, local_steps, batch_per_step, cfg.num_encoder_positions, cfg.d_model),
            jnp.bfloat16)
    mask = jax.ShapeDtypeStruct((M,), jnp.float32)
    stal = jax.ShapeDtypeStruct((M,), jnp.float32)
    sizes = jax.ShapeDtypeStruct((M,), jnp.float32)
    return b, mask, stal, sizes
