"""Event-driven semi-asynchronous scheduler (§IV-C), with fault injection.

Deterministically simulates the paper's timing behaviour: each client's
per-round training latency follows the paper's own measurements (§V-D3:
C0 |D|=78357 -> 317 s, C9 |D|=16904 -> 166 s), i.e.

    t_i = 124.47 + 0.0024571 * |D_i|   seconds (+ optional jitter)

The server aggregates as soon as ceil(C*M) uploads are queued
(semi-asynchronous model update); clients that are still training keep
running on their stale base version (staleness-tolerant distribution) unless
their version gap exceeds tau, in which case they are forced to restart from
the new global model (deprecated). ART (average round time) falls out of the
simulated clock, reproducing Table VIII.

Fault injection (``traffic=``, a :class:`~repro.core.traffic.TrafficModel`)
drives the unhappy paths through the same event loop: heavy-tailed run
latencies, crash-mid-run (the run dies and the client retries from its
persisted base — staleness emerges instead of being scripted), upload loss
(the run finishes but the payload never arrives: the client becomes a
distribution target of the next round, not a participant), leave/rejoin
churn (an in-flight run is cancelled at leave; a rejoiner waits for the
next boundary to be re-based) and late joins.  Churn transitions live in
their own event heap merged with the run heap at pop time, so the run heap
keeps its legacy ``(finish_time, seq, run)`` layout.

Graceful degradation: with a ``deadline`` (seconds of simulated time per
round), a round that cannot gather ``k = ceil(C*M)`` uploads in time
aggregates a *degraded quorum* — whatever arrived, down to
``quorum_floor`` — instead of blocking forever, and reports the
degradation in the round result.  When fewer than the quorum floor of
uploads can ever arrive (no live runs left — fleet churned out or crashed
dry), :meth:`next_round` raises :class:`FleetStalledError` instead of the
bare ``heapq`` ``IndexError`` / infinite loop the happy-path loop had.

``next_round`` returns a :class:`RoundResult`; legacy callers that unpack
``participants, stale, forced, t`` keep working (the result iterates as
that 4-tuple), while the fault-aware trainer reads the extra fields
(``lost``, ``departed``, ``rejoined``, ``degraded``, ``quorum``, ...).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

A_LAT = 124.47
B_LAT = 0.0024571

# hard per-round event budget: a pathological fault profile (e.g. every
# client stuck in a crash-retry loop) must surface as a clear error, not a
# hang — next_round processes at most this many events before declaring
# the fleet stalled
MAX_EVENTS_PER_ROUND = 100_000


def paper_latency(n_samples: int) -> float:
    return A_LAT + B_LAT * n_samples


class FleetStalledError(RuntimeError):
    """The fleet cannot reach the quorum floor: fewer than ``quorum_floor``
    uploads can still arrive (no live runs left, or the per-round event
    budget was exhausted by unproductive events)."""


@dataclass
class ClientRun:
    client: int
    base_version: int      # global round the client's base model came from
    finish_time: float     # upload arrival (or crash) instant
    fate: str = "ok"       # "ok" | "crash" | "lost" | "corrupt" —
                           # sampled at start


@dataclass
class RoundResult:
    """One aggregation boundary. Iterates as the legacy 4-tuple
    ``(participants, stale, forced, time)``; the fault-aware fields ride
    along as attributes."""

    participants: list     # delivered ClientRuns, arrival order
    stale: dict            # client -> rounds stale at aggregation
    forced: list           # clients force-restarted (version gap > tau)
    time: float            # simulated clock at aggregation
    lost: list = field(default_factory=list)      # uploads lost in transit
    corrupted: list = field(default_factory=list)  # uploads that arrived
                                                   # malformed and were
                                                   # quarantined
    departed: list = field(default_factory=list)  # clients that left
    rejoined: list = field(default_factory=list)  # clients back online
    resynced: list = field(default_factory=list)  # filled by the trainer:
                                                  # rejoiners needing a
                                                  # full-model resync
    crashes: int = 0       # crash-mid-run events this round
    degraded: bool = False     # aggregated below the k target
    deadline_hit: bool = False  # the round deadline forced the aggregation
    quorum: int = 0        # delivered uploads actually aggregated
    target_k: int = 0      # the participation threshold k

    def __iter__(self):
        return iter((self.participants, self.stale, self.forced, self.time))


@dataclass
class SchedulerState:
    time: float = 0.0
    round: int = 0
    runs: list = field(default_factory=list)          # heap of (t, seq, run)
    events: list = field(default_factory=list)        # heap of churn
                                                      # (t, seq, kind, client)
    versions: dict = field(default_factory=dict)      # client -> base version
    online: dict = field(default_factory=dict)        # client -> available?
    run_seq: dict = field(default_factory=dict)       # client -> live run seq
    cancelled: set = field(default_factory=set)       # seqs of cancelled runs
    live_runs: int = 0
    # per-round scratch, drained at each boundary
    pending_lost: list = field(default_factory=list)
    pending_corrupt: list = field(default_factory=list)
    pending_rejoin: set = field(default_factory=set)
    pending_departed: list = field(default_factory=list)
    _seq: int = 0


class SemiAsyncScheduler:
    """Drives the FedS3A timing loop; the trainer plugs in the learning."""

    def __init__(self, latencies, *, C=0.6, tau=2, jitter=0.0, seed=0,
                 traffic=None, deadline=None, quorum_floor=1,
                 max_events_per_round=MAX_EVENTS_PER_ROUND):
        self.latencies = list(latencies)
        self.M = len(self.latencies)
        self.k = max(int(math.ceil(C * self.M)), 1)
        self.tau = tau
        self.jitter = jitter
        self.traffic = traffic
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be positive, got {deadline}")
        self.deadline = deadline
        if not 1 <= int(quorum_floor) <= self.k:
            raise ValueError(f"quorum_floor must be in [1, k={self.k}], "
                             f"got {quorum_floor}")
        self.quorum_floor = int(quorum_floor)
        self.max_events_per_round = max_events_per_round
        import numpy as np
        self._rng = np.random.default_rng(seed)
        # faults draw from their own stream so enabling them never perturbs
        # the fault-free schedule (the jitter rng is untouched)
        self._traffic_rng = np.random.default_rng((seed, 0x7a11))
        self.state = SchedulerState()
        st = self.state
        self.initial_offline = traffic.initial_offline(
            self._traffic_rng, self.M) if traffic is not None else []
        offline = set(self.initial_offline)
        for i in range(self.M):
            st.versions[i] = 0
            st.online[i] = i not in offline
            if st.online[i]:
                self._start_run(i, 0, st.time)
                self._schedule_leave(i, st.time)
            else:
                self._schedule_join(i, st.time)

    # -- event construction ------------------------------------------------
    def _lat(self, i):
        if self.jitter:
            return self.latencies[i] * float(
                self._rng.uniform(1 - self.jitter, 1 + self.jitter))
        return self.latencies[i]

    def _start_run(self, client, base_version, start_time):
        st = self.state
        lat = self._lat(client)
        fate = "ok"
        if self.traffic is not None:
            lat *= self.traffic.latency_multiplier(self._traffic_rng)
            fate, frac = self.traffic.run_fate(self._traffic_rng)
            if fate == "crash":
                # the run dies partway through; the upload is never born
                lat *= max(frac, 1e-6)
        run = ClientRun(client, base_version, start_time + lat, fate)
        heapq.heappush(st.runs, (run.finish_time, st._seq, run))
        st.run_seq[client] = st._seq
        st._seq += 1
        st.live_runs += 1

    def _cancel_run(self, client):
        """Cancel the client's in-flight run (lazily: the heap entry is
        skipped when popped / purged at the next forced scan)."""
        st = self.state
        seq = st.run_seq.pop(client, None)
        if seq is not None:
            st.cancelled.add(seq)
            st.live_runs -= 1

    def _schedule_leave(self, client, now):
        if self.traffic is None or not self.traffic.churns:
            return
        st = self.state
        dur = self.traffic.online_duration(self._traffic_rng)
        if math.isfinite(dur):
            heapq.heappush(st.events, (now + dur, st._seq, "leave", client))
            st._seq += 1

    def _schedule_join(self, client, now):
        st = self.state
        dur = self.traffic.offline_duration(self._traffic_rng)
        heapq.heappush(st.events, (now + dur, st._seq, "join", client))
        st._seq += 1

    # -- event processing --------------------------------------------------
    def _process_churn(self, kind, client, t):
        st = self.state
        if kind == "leave":
            if not st.online[client]:
                return
            st.online[client] = False
            self._cancel_run(client)
            if client in st.pending_rejoin:
                # joined and left again between boundaries: it never
                # re-attached, so there is nothing to retire
                st.pending_rejoin.discard(client)
            else:
                st.pending_departed.append(client)
            if client in st.pending_lost:
                st.pending_lost.remove(client)
            if client in st.pending_corrupt:
                st.pending_corrupt.remove(client)
            self._schedule_join(client, t)
        else:  # join
            if st.online[client]:
                return
            st.online[client] = True
            st.pending_rejoin.add(client)
            self._schedule_leave(client, t)

    def next_round(self):
        """Advance until k uploads arrive — or the deadline passes with at
        least ``quorum_floor`` of them (degraded round). Returns a
        :class:`RoundResult` (legacy callers unpack it as
        ``participants, stale, forced, time``).

        Raises :class:`FleetStalledError` when fewer than the quorum floor
        of uploads can still arrive: no live runs remain (the fleet
        churned out, crashed dry, or ``k`` exceeds the online fleet) or
        the per-round event budget is exhausted.
        """
        st = self.state
        deadline_t = (st.time + self.deadline) if self.deadline is not None \
            else math.inf
        arrivals = []
        crashes = 0
        degraded = deadline_hit = False
        processed = 0
        while len(arrivals) < self.k:
            t_run = st.runs[0][0] if st.runs else math.inf
            t_ev = st.events[0][0] if st.events else math.inf
            t_next = min(t_run, t_ev)
            if len(arrivals) >= self.quorum_floor and t_next > deadline_t:
                # deadline passed before the k-th upload: aggregate the
                # degraded quorum at the deadline instant
                degraded = deadline_hit = True
                st.time = max(st.time, deadline_t)
                break
            if st.live_runs == 0:
                # nothing in flight can ever produce another upload
                if len(arrivals) >= self.quorum_floor:
                    degraded = True
                    break
                raise FleetStalledError(
                    f"fleet stalled at t={st.time:.1f}s: {len(arrivals)} "
                    f"upload(s) arrived, quorum floor is "
                    f"{self.quorum_floor} (k={self.k}) and no runs are in "
                    f"flight — every remaining client is offline or dead")
            processed += 1
            if processed > self.max_events_per_round:
                raise FleetStalledError(
                    f"fleet stalled: {self.max_events_per_round} events "
                    f"processed without reaching the quorum floor "
                    f"({len(arrivals)}/{self.quorum_floor} uploads) — "
                    f"the fault profile starves the fleet of uploads")
            if t_ev <= t_run:
                t, _, kind, client = heapq.heappop(st.events)
                st.time = max(st.time, t)
                self._process_churn(kind, client, t)
                continue
            t, seq, run = heapq.heappop(st.runs)
            if seq in st.cancelled:
                st.cancelled.discard(seq)
                continue
            st.time = max(st.time, t)
            st.run_seq.pop(run.client, None)
            st.live_runs -= 1
            if run.fate == "crash":
                # reboot and retry from the persisted base: staleness (and
                # eventually tau-forcing) emerges from the lost time
                crashes += 1
                self._start_run(run.client, run.base_version, st.time)
            elif run.fate == "lost":
                # the upload evaporated in transit; the client waits for
                # the next broadcast like any other uploader
                st.pending_lost.append(run.client)
            elif run.fate == "corrupt":
                # the payload arrived malformed; the server's wire
                # validation quarantines it and the client — like a lost
                # uploader — waits for the next broadcast
                st.pending_corrupt.append(run.client)
            else:
                arrivals.append(run)

        participants = arrivals
        round_idx = st.round

        stale = {run.client: round_idx - run.base_version
                 for run in participants}
        new_version = round_idx + 1

        # distribution: delivered clients still online restart from the new
        # model (a participant that left after uploading stays aggregated
        # but gets no new run)
        for run in participants:
            if st.online[run.client]:
                st.versions[run.client] = new_version
                self._start_run(run.client, new_version, st.time)

        # staleness-tolerant distribution for everyone still training;
        # purge cancelled heap entries while scanning
        forced = []
        kept = []
        changed = False
        for (t, seq, run) in st.runs:
            if seq in st.cancelled:
                st.cancelled.discard(seq)
                changed = True
                continue
            gap = new_version - run.base_version
            if gap > self.tau:
                forced.append(run)
                changed = True
            else:
                kept.append((t, seq, run))
        if changed:
            st.runs = kept
            heapq.heapify(st.runs)
            for run in forced:
                st.run_seq.pop(run.client, None)
                st.live_runs -= 1
                st.versions[run.client] = new_version
                self._start_run(run.client, new_version, st.time)

        # lost-upload clients receive the broadcast and start over;
        # quarantined uploaders follow the identical path (their payload
        # arrived but was rejected, so from the model's point of view it
        # was never delivered)
        lost = sorted(st.pending_lost)
        corrupted = sorted(st.pending_corrupt)
        for c in lost + corrupted:
            st.versions[c] = new_version
            self._start_run(c, new_version, st.time)

        # rejoiners re-base at the boundary (chain suffix or full resync —
        # the trainer's store decides) and start their first new run. A
        # participant that departed and rejoined within the round was
        # already restarted by the participants loop (it is back online) —
        # the run_seq guard keeps it from getting a second run.
        rejoined = sorted(st.pending_rejoin)
        for c in rejoined:
            if c not in st.run_seq:
                st.versions[c] = new_version
                self._start_run(c, new_version, st.time)

        departed = sorted(set(st.pending_departed))
        st.pending_lost = []
        st.pending_corrupt = []
        st.pending_rejoin = set()
        st.pending_departed = []

        st.round = new_version
        return RoundResult(
            participants=participants, stale=stale,
            forced=[r.client for r in forced], time=st.time,
            lost=lost, corrupted=corrupted, departed=departed,
            rejoined=rejoined,
            crashes=crashes, degraded=degraded, deadline_hit=deadline_hit,
            quorum=len(participants), target_k=self.k)

    # -- checkpoint / restore ----------------------------------------------
    def state_dict(self):
        """The scheduler's complete mutable state as plain data (lists,
        dicts, numbers, strings) — both heaps in their underlying list
        order (which already satisfies the heap invariant, so restore is a
        straight copy-in), every pending scratch list, and the exact
        bit-generator state of BOTH RNG streams (latency jitter and fault
        traffic). Restoring onto a scheduler built with the same
        constructor arguments reproduces the identical ``next_round()``
        sequence, draw for draw.

        The RNG entries are ``numpy`` ``bit_generator.state`` dicts and may
        contain >64-bit integers; callers serializing to formats without
        bignums (msgpack) must encode those themselves.
        """
        st = self.state
        return {
            "M": self.M,
            "time": float(st.time),
            "round": int(st.round),
            "runs": [[float(t), int(seq),
                      [int(r.client), int(r.base_version),
                       float(r.finish_time), str(r.fate)]]
                     for (t, seq, r) in st.runs],
            "events": [[float(t), int(seq), str(kind), int(c)]
                       for (t, seq, kind, c) in st.events],
            "versions": [[int(c), int(v)] for c, v in st.versions.items()],
            "online": [[int(c), bool(v)] for c, v in st.online.items()],
            "run_seq": [[int(c), int(s)] for c, s in st.run_seq.items()],
            "cancelled": sorted(int(s) for s in st.cancelled),
            "live_runs": int(st.live_runs),
            "pending_lost": [int(c) for c in st.pending_lost],
            "pending_corrupt": [int(c) for c in st.pending_corrupt],
            "pending_rejoin": sorted(int(c) for c in st.pending_rejoin),
            "pending_departed": [int(c) for c in st.pending_departed],
            "seq": int(st._seq),
            "rng": self._rng.bit_generator.state,
            "traffic_rng": self._traffic_rng.bit_generator.state,
        }

    def load_state_dict(self, d):
        """Restore :meth:`state_dict` output. The scheduler must have been
        constructed with the same fleet (``M`` is checked; the caller owns
        matching C/tau/jitter/traffic/seed — a mismatch there silently
        diverges, which is why the trainer fingerprints its full config)."""
        if int(d["M"]) != self.M:
            raise ValueError(f"scheduler state is for a fleet of "
                             f"{d['M']} clients, this scheduler has "
                             f"{self.M}")
        st = SchedulerState()
        st.time = float(d["time"])
        st.round = int(d["round"])
        st.runs = [(float(t), int(seq),
                    ClientRun(int(c), int(b), float(f), str(fate)))
                   for (t, seq, (c, b, f, fate)) in d["runs"]]
        st.events = [(float(t), int(seq), str(kind), int(c))
                     for (t, seq, kind, c) in d["events"]]
        st.versions = {int(c): int(v) for c, v in d["versions"]}
        st.online = {int(c): bool(v) for c, v in d["online"]}
        st.run_seq = {int(c): int(s) for c, s in d["run_seq"]}
        st.cancelled = set(int(s) for s in d["cancelled"])
        st.live_runs = int(d["live_runs"])
        st.pending_lost = [int(c) for c in d["pending_lost"]]
        st.pending_corrupt = [int(c) for c in d.get("pending_corrupt", [])]
        st.pending_rejoin = set(int(c) for c in d["pending_rejoin"])
        st.pending_departed = [int(c) for c in d["pending_departed"]]
        st._seq = int(d["seq"])
        self._rng.bit_generator.state = d["rng"]
        self._traffic_rng.bit_generator.state = d["traffic_rng"]
        self.state = st
