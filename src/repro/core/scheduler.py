"""Event-driven semi-asynchronous scheduler (§IV-C).

Deterministically simulates the paper's timing behaviour: each client's
per-round training latency follows the paper's own measurements (§V-D3:
C0 |D|=78357 -> 317 s, C9 |D|=16904 -> 166 s), i.e.

    t_i = 124.47 + 0.0024571 * |D_i|   seconds (+ optional jitter)

The server aggregates as soon as ceil(C*M) uploads are queued
(semi-asynchronous model update); clients that are still training keep
running on their stale base version (staleness-tolerant distribution) unless
their version gap exceeds tau, in which case they are forced to restart from
the new global model (deprecated). ART (average round time) falls out of the
simulated clock, reproducing Table VIII.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

A_LAT = 124.47
B_LAT = 0.0024571


def paper_latency(n_samples: int) -> float:
    return A_LAT + B_LAT * n_samples


@dataclass
class ClientRun:
    client: int
    base_version: int      # global round the client's base model came from
    finish_time: float


@dataclass
class SchedulerState:
    time: float = 0.0
    round: int = 0
    runs: list = field(default_factory=list)          # heap of (t, seq, run)
    staleness: dict = field(default_factory=dict)     # client -> rounds stale
    versions: dict = field(default_factory=dict)      # client -> base version
    _seq: int = 0


class SemiAsyncScheduler:
    """Drives the FedS3A timing loop; the trainer plugs in the learning."""

    def __init__(self, latencies, *, C=0.6, tau=2, jitter=0.0, seed=0):
        self.latencies = list(latencies)
        self.M = len(self.latencies)
        self.k = max(int(math.ceil(C * self.M)), 1)
        self.tau = tau
        self.jitter = jitter
        import numpy as np
        self._rng = np.random.default_rng(seed)
        self.state = SchedulerState()
        for i in range(self.M):
            self.state.versions[i] = 0
            self.state.staleness[i] = 0
            self._start_run(i, 0, self.state.time)

    def _lat(self, i):
        if self.jitter:
            return self.latencies[i] * float(
                self._rng.uniform(1 - self.jitter, 1 + self.jitter))
        return self.latencies[i]

    def _start_run(self, client, base_version, start_time):
        st = self.state
        run = ClientRun(client, base_version, start_time + self._lat(client))
        heapq.heappush(st.runs, (run.finish_time, st._seq, run))
        st._seq += 1

    def next_round(self):
        """Advance until k uploads arrive. Returns (round_info, round_time).

        round_info: list of ClientRun that participate in this aggregation,
        in arrival order; staleness per run = current_round - base_version.
        """
        st = self.state
        arrivals = []
        while len(arrivals) < self.k:
            t, _, run = heapq.heappop(st.runs)
            st.time = max(st.time, t)
            arrivals.append(run)
        participants = arrivals
        round_idx = st.round

        stale = {run.client: round_idx - run.base_version for run in participants}
        new_version = round_idx + 1

        # distribution: latest clients restart from the new model
        for run in participants:
            st.versions[run.client] = new_version
            self._start_run(run.client, new_version, st.time)

        # staleness-tolerant distribution for everyone still training
        forced = []
        kept = []
        for (t, seq, run) in st.runs:
            gap = new_version - run.base_version
            if gap > self.tau:
                forced.append(run)
            else:
                kept.append((t, seq, run))
        if forced:
            st.runs = kept
            heapq.heapify(st.runs)
            for run in forced:
                st.versions[run.client] = new_version
                self._start_run(run.client, new_version, st.time)

        st.round = new_version
        return participants, stale, [r.client for r in forced], st.time
