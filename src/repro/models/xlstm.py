"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, recurrent with block-diagonal recurrent weights).

The chunkwise mLSTM follows the stabilized formulation of the paper's appendix:
log-sigmoid forget gates, exponential input gates, running max stabilizer ``m``.
``mlstm_decode`` is the exact per-step recurrence — it doubles as the oracle
for the chunked form (see tests/test_xlstm.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, pdtype, cdtype

NEG = -1e30


def _heads(cfg: ModelConfig):
    H = cfg.num_heads
    di = 2 * cfg.d_model
    dh = di // H
    return H, di, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(cfg: ModelConfig, rng):
    d = cfg.d_model
    H, di, dh = _heads(cfg)
    ks = jax.random.split(rng, 8)
    return {
        "up": _dense_init(ks[0], (d, 2 * di), pdtype(cfg)),
        "wq": _dense_init(ks[1], (di, di), pdtype(cfg)),
        "wk": _dense_init(ks[2], (di, di), pdtype(cfg)),
        "wv": _dense_init(ks[3], (di, di), pdtype(cfg)),
        "wi": _dense_init(ks[4], (di, H), pdtype(cfg), scale=0.02),
        "wf": _dense_init(ks[5], (di, H), pdtype(cfg), scale=0.02),
        "bf": jnp.full((H,), 3.0, pdtype(cfg)),  # open forget gates at init
        "bi": jnp.zeros((H,), pdtype(cfg)),
        "down": _dense_init(ks[6], (di, d), pdtype(cfg)),
    }


def _mlstm_qkvif(cfg, params, u):
    """u: (..., di) -> q,k,v (..., H, dh); i,f raw gates (..., H)."""
    H, di, dh = _heads(cfg)
    dt = u.dtype
    q = (u @ params["wq"].astype(dt)).reshape(*u.shape[:-1], H, dh)
    k = (u @ params["wk"].astype(dt)).reshape(*u.shape[:-1], H, dh)
    v = (u @ params["wv"].astype(dt)).reshape(*u.shape[:-1], H, dh)
    i_raw = u @ params["wi"].astype(dt) + params["bi"].astype(dt)
    f_raw = u @ params["wf"].astype(dt) + params["bf"].astype(dt)
    q = q / math.sqrt(dh)
    return q, k, v, i_raw.astype(jnp.float32), f_raw.astype(jnp.float32)


def mlstm_cell_chunked(q, k, v, i_raw, f_raw, state=None, *, chunk=128):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,dh) with q pre-scaled by 1/sqrt(dh); i_raw,f_raw: (B,S,H) fp32.
    state: optional (C (B,H,dh,dh), n (B,H,dh), m (B,H)).
    Returns h (B,S,H,dh), final state.
    """
    B, S, H, dh = q.shape
    L = min(chunk, S)
    if S % L != 0:
        L = S
    nc = S // L

    # (nc, B, H, L, ...) layout
    def arr(x, tail):
        return x.reshape(B, nc, L, H, *tail).transpose(1, 0, 3, 2, *range(4, 4 + len(tail)))
    qc, kc, vc = (arr(x, (dh,)) for x in (q, k, v))
    ic = i_raw.reshape(B, nc, L, H).transpose(1, 0, 3, 2)   # (nc,B,H,L)
    fc = jax.nn.log_sigmoid(f_raw).reshape(B, nc, L, H).transpose(1, 0, 3, 2)

    if state is None:
        from repro.distributed.sharding import maybe_constraint
        ba = ("pod", "data")
        C0 = maybe_constraint(jnp.zeros((B, H, dh, dh), jnp.float32),
                              (ba, "model", None, None))
        n0 = maybe_constraint(jnp.zeros((B, H, dh), jnp.float32),
                              (ba, "model", None))
        m0 = maybe_constraint(jnp.full((B, H), NEG, jnp.float32),
                              (ba, "model"))
        state = (C0, n0, m0)

    tri = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, inp):
        C0, n0, m0 = carry
        qt, kt, vt, it, ft = inp                      # (B,H,L,dh)/(B,H,L)
        F = jnp.cumsum(ft, axis=-1)                   # (B,H,L) inclusive
        logD = F[..., :, None] - F[..., None, :] + it[..., None, :]
        logD = jnp.where(tri, logD, NEG)              # (B,H,L,L)
        a = F + m0[..., None]                         # state log-weight (B,H,L)
        m = jnp.maximum(jnp.max(logD, axis=-1), a)    # (B,H,L)
        w = jnp.exp(logD - m[..., None])              # (B,H,L,L)
        sw = jnp.exp(a - m)                           # (B,H,L)

        qk = jnp.einsum("bhld,bhsd->bhls", qt.astype(jnp.float32), kt.astype(jnp.float32))
        num = jnp.einsum("bhls,bhsd->bhld", w * qk, vt.astype(jnp.float32))
        num = num + sw[..., None] * jnp.einsum("bhld,bhde->bhle", qt.astype(jnp.float32), C0)
        den = jnp.einsum("bhls,bhls->bhl", w, qk)
        den = den + sw * jnp.einsum("bhld,bhd->bhl", qt.astype(jnp.float32), n0)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

        # carry to next chunk
        Fl = F[..., -1]                               # (B,H)
        lw = Fl[..., None] - F + it                   # (B,H,L) kv weights to chunk end
        m_next = jnp.maximum(Fl + m0, jnp.max(lw, axis=-1))
        wkv = jnp.exp(lw - m_next[..., None])
        C = jnp.exp(Fl + m0 - m_next)[..., None, None] * C0 + jnp.einsum(
            "bhl,bhld,bhle->bhde", wkv, kt.astype(jnp.float32), vt.astype(jnp.float32))
        n = jnp.exp(Fl + m0 - m_next)[..., None] * n0 + jnp.einsum(
            "bhl,bhld->bhd", wkv, kt.astype(jnp.float32))
        return (C, n, m_next), h

    state, hs = lax.scan(body, state, (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dh)
    return h, state


def mlstm_cell_step(q, k, v, i_raw, f_raw, state):
    """Exact single-step recurrence. q,k,v: (B,H,dh) (q pre-scaled); gates (B,H)."""
    C0, n0, m0 = state
    f_log = jax.nn.log_sigmoid(f_raw)
    m = jnp.maximum(f_log + m0, i_raw)
    fp = jnp.exp(f_log + m0 - m)
    ip = jnp.exp(i_raw - m)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C = fp[..., None, None] * C0 + ip[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n = fp[..., None] * n0 + ip[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    return h, (C, n, m)


def mlstm(cfg: ModelConfig, params, x, *, chunk=128):
    """mLSTM block forward. x: (B,S,d)."""
    dt = cdtype(cfg)
    B, S, d = x.shape
    H, di, dh = _heads(cfg)
    uz = x @ params["up"].astype(dt)
    u, z = jnp.split(uz, 2, axis=-1)
    q, k, v, i_raw, f_raw = _mlstm_qkvif(cfg, params, u)
    h, _ = mlstm_cell_chunked(q, k, v, i_raw, f_raw, chunk=chunk)
    h = h.reshape(B, S, di).astype(dt) * jax.nn.silu(z)
    return h @ params["down"].astype(dt)


def mlstm_decode(cfg: ModelConfig, params, x, state):
    """One-token decode. x: (B,d); state = (C,n,m)."""
    dt = cdtype(cfg)
    B, d = x.shape
    H, di, dh = _heads(cfg)
    uz = x @ params["up"].astype(dt)
    u, z = jnp.split(uz, 2, axis=-1)
    q, k, v, i_raw, f_raw = _mlstm_qkvif(cfg, params, u)
    h, state = mlstm_cell_step(q, k, v, i_raw, f_raw, state)
    h = h.reshape(B, di).astype(dt) * jax.nn.silu(z)
    return h @ params["down"].astype(dt), state


def init_mlstm_state(cfg: ModelConfig, batch):
    H, di, dh = _heads(cfg)
    return (
        jnp.zeros((batch, H, dh, dh), jnp.float32),
        jnp.zeros((batch, H, dh), jnp.float32),
        jnp.full((batch, H), NEG, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(cfg: ModelConfig, rng):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(rng, 4)
    return {
        "w": _dense_init(ks[0], (d, 4 * d), pdtype(cfg)),             # z,i,f,o
        "r": _dense_init(ks[1], (4, H, dh, dh), pdtype(cfg), scale=1.0 / math.sqrt(dh)),
        "b": jnp.concatenate([
            jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))
        ]).astype(pdtype(cfg)),
        "up": _dense_init(ks[2], (d, 4 * d), pdtype(cfg)),            # gated FFN
        "down": _dense_init(ks[3], (2 * d, d), pdtype(cfg)),
    }


def _slstm_step(cfg, params, x_t, state):
    """x_t: (B,d). state = (c,n,m,h) each (B,H,dh) / h (B,d)."""
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    c, n, m, h_prev = state
    dt = x_t.dtype
    g = x_t @ params["w"].astype(dt) + params["b"].astype(dt)
    hp = h_prev.reshape(-1, H, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hp, params["r"].astype(dt))    # (4,B,H,dh)
    g = g.reshape(-1, 4, H, dh) + jnp.moveaxis(rec, 0, 1)
    z_r, i_r, f_r, o_r = (g[:, j].astype(jnp.float32) for j in range(4))
    z = jnp.tanh(z_r)
    f_log = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(f_log + m, i_r)
    fp = jnp.exp(f_log + m - m_new)
    ip = jnp.exp(i_r - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1e-6)
    h_flat = h.reshape(-1, d).astype(jnp.float32)   # carry stays fp32
    return (c, n, m_new, h_flat), h_flat


def init_slstm_state(cfg: ModelConfig, batch):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, jnp.full((batch, H, dh), NEG, jnp.float32),
            jnp.zeros((batch, d), jnp.float32))


def slstm(cfg: ModelConfig, params, x, *, return_state=False):
    """sLSTM block forward (recurrent over S). x: (B,S,d)."""
    from repro.distributed.sharding import maybe_constraint
    dt = cdtype(cfg)
    B, S, d = x.shape
    state = init_slstm_state(cfg, B)
    state = jax.tree.map(
        lambda t: maybe_constraint(t.astype(jnp.float32),
                                   (("pod", "data"),) + (None,) * (t.ndim - 1)),
        state)
    step = lambda st, xt: _slstm_step(cfg, params, xt, st)
    state, hs = lax.scan(step, state, x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                                             # (B,S,d)
    uz = h.astype(dt) @ params["up"].astype(dt)
    u, z = jnp.split(uz, 2, axis=-1)
    out = (jax.nn.silu(z) * u) @ params["down"].astype(dt)
    if return_state:
        return out, state
    return out


def slstm_decode(cfg: ModelConfig, params, x, state):
    dt = cdtype(cfg)
    state, h = _slstm_step(cfg, params, x, state)
    uz = h.astype(dt) @ params["up"].astype(dt)
    u, z = jnp.split(uz, 2, axis=-1)
    return (jax.nn.silu(z) * u) @ params["down"].astype(dt), state
