"""Core neural layers: norm, RoPE, GQA/MLA/sliding-window attention, MLP, MoE,
Mamba selective scan (chunked), xLSTM (mLSTM chunked-parallel + sLSTM recurrent).

Convention: every layer is a pair of pure functions
  ``init_<layer>(cfg, rng) -> params``   (pytree of jnp arrays, param_dtype)
  ``<layer>(cfg, params, x, ...) -> y``  (compute in cfg.dtype)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _dense_init(rng, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(cfg, rng, dim=None):
    dim = dim or cfg.d_model
    return {"scale": jnp.ones((dim,), pdtype(cfg))}


def rmsnorm(cfg, params, x):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + cfg.norm_eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(cfg, dim):
    half = dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv  # (half,)


def apply_rope(cfg, x, positions, dim=None):
    """x: (..., S, H, hd) or (..., H, hd) with positions broadcastable to (..., S)."""
    dim = dim or x.shape[-1]
    inv = rope_freqs(cfg, dim)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin = sin[..., None, :]  # broadcast over head axis
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention, XLA-level (nested lax.scan over q/k blocks, online softmax).
# Structural twin of kernels/flash_attention.py; keeps peak memory at
# B*H*qblk*kblk instead of B*H*Sq*Sk. Default for long-sequence train/prefill.
# ---------------------------------------------------------------------------
# default flash tile sizes; the launcher/perf pass overrides via
# set_flash_blocks (bigger tiles = higher arithmetic intensity per HBM byte,
# bounded by VMEM)
FLASH_BLOCKS = {"qblk": 512, "kblk": 512, "tile_bf16": False,
                "constrain": True}


def set_flash_blocks(qblk, kblk, tile_bf16=None, constrain=None):
    FLASH_BLOCKS["qblk"] = qblk
    FLASH_BLOCKS["kblk"] = kblk
    if tile_bf16 is not None:
        FLASH_BLOCKS["tile_bf16"] = tile_bf16
    if constrain is not None:
        # under vmap (FL client axis) the internal batch/head constraints
        # fight the mapped-axis sharding and GSPMD inserts resharding
        # all-to-alls; the FL launcher disables them
        FLASH_BLOCKS["constrain"] = constrain


def flash_attention_xla(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                        qblk=None, kblk=None):
    """Remat wrapper: without it, scan autodiff stashes every (qblk x kblk)
    probability tile as a stacked residual — O(S^2) memory, exactly what flash
    attention exists to avoid. Backward recomputes the tiles instead (the
    standard flash-backward trade)."""
    f = partial(_flash_attention_xla_impl, causal=causal, window=window,
                qblk=qblk or FLASH_BLOCKS["qblk"],
                kblk=kblk or FLASH_BLOCKS["kblk"])
    return jax.checkpoint(f)(q, k, v, q_pos, k_pos)


def _flash_attention_xla_impl(q, k, v, q_pos, k_pos, *, causal=True,
                              window=None, qblk=512, kblk=512):
    """q: (B,Sq,Hq,hd); k/v: (B,Sk,Hkv,hd). positions: (B,Sq)/(B,Sk).

    GQA KV heads are pre-broadcast to the full head count so the head dim
    shards cleanly over the model axis; every loop-carried tensor carries an
    explicit sharding constraint — otherwise GSPMD replicates the whole loop
    body across the batch axis (measured 16x FLOP blowup on the dry-run).
    """
    from repro.distributed.sharding import maybe_constraint as _mc
    maybe_constraint = _mc if FLASH_BLOCKS["constrain"] else (lambda x, s: x)
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    hdv = v.shape[-1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qblk = min(qblk, Sq)
    kblk = min(kblk, Sk)
    if Sq % qblk or Sk % kblk:
        mask = _causal_mask(q_pos, k_pos, window) if causal else None
        return _sdpa(q, k, v, mask, scale)
    nq, nk = Sq // qblk, Sk // kblk
    if G > 1:  # broadcast KV to all heads: clean head sharding on the mesh
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    ba = ("pod", "data")
    blk_spec = (None, ba, "model", None, None)

    qb = q.reshape(B, nq, qblk, Hq, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qblk,hd)
    kb = k.reshape(B, nk, kblk, Hq, hd).transpose(1, 0, 3, 2, 4)   # (nk,B,H,kblk,hd)
    vb = v.reshape(B, nk, kblk, Hq, hdv).transpose(1, 0, 3, 2, 4)
    qb = maybe_constraint(qb, blk_spec)
    kb = maybe_constraint(kb, blk_spec)
    vb = maybe_constraint(vb, blk_spec)

    # Positions are derived from the scan counters (qi*qblk + iota), NOT from
    # precomputed position tensors: loop-invariant position blocks get hoisted
    # by XLA into a materialized O(S^2) boolean mask (measured: dominated HBM
    # traffic on the dry-run).
    iq = jnp.arange(qblk, dtype=jnp.int32)
    ik = jnp.arange(kblk, dtype=jnp.int32)

    def q_block(_, xs_q):
        qi, qidx = xs_q                              # (B,H,qblk,hd), scalar
        qp = qidx * qblk + iq                        # (qblk,)
        m0 = maybe_constraint(jnp.full((B, Hq, qblk), -1e30, jnp.float32),
                              (ba, "model", None))
        l0 = maybe_constraint(jnp.zeros((B, Hq, qblk), jnp.float32),
                              (ba, "model", None))
        a0 = maybe_constraint(jnp.zeros((B, Hq, qblk, hdv), jnp.float32),
                              (ba, "model", None, None))

        def k_block(carry, xs_k):
            m, l, acc = carry
            ki, vi, kidx = xs_k
            kp = kidx * kblk + ik                    # (kblk,)
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, ki).astype(jnp.float32) * scale
            if causal:
                ok = kp[None, :] <= qp[:, None]      # (qblk,kblk)
                if window is not None:
                    ok &= kp[None, :] > (qp[:, None] - window)
                s = jnp.where(ok[None, None], s, -1e30)
            if FLASH_BLOCKS["tile_bf16"]:
                # tile traffic in bf16 (stats stay f32) — models the Pallas
                # kernel's VMEM residency; halves the dominant HBM term
                s = s.astype(jnp.bfloat16).astype(jnp.float32)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vi.dtype), vi).astype(jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = lax.scan(
            k_block, (m0, l0, a0), (kb, vb, jnp.arange(nk, dtype=jnp.int32)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = lax.scan(q_block, None, (qb, jnp.arange(nq, dtype=jnp.int32)))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, Hq, hdv)
    return out


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, rng):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 5)
    p = {
        "wq": _dense_init(ks[0], (d, nq * hd), pdtype(cfg)),
        "wk": _dense_init(ks[1], (d, nkv * hd), pdtype(cfg)),
        "wv": _dense_init(ks[2], (d, nkv * hd), pdtype(cfg)),
        "wo": _dense_init(ks[3], (nq * hd, d), pdtype(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), pdtype(cfg))
        p["bk"] = jnp.zeros((nkv * hd,), pdtype(cfg))
        p["bv"] = jnp.zeros((nkv * hd,), pdtype(cfg))
    return p


def _causal_mask(q_pos, k_pos, window):
    """(..., Sq, Sk) boolean mask. True = attend."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,Hq,hd), k/v: (B,Sk,Hkv,hd_v) with Hq = G*Hkv (hd_v may differ)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    hd_v = v.shape[3]
    G = Hq // Hkv
    q = q.reshape(B, Sq, Hkv, G, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, Hq, hd_v)


def attention(cfg: ModelConfig, params, x, positions, *, window=None,
              kv_override=None, causal=True, impl="ref"):
    """Full (or sliding-window) self-attention; cross-attention when
    ``kv_override`` supplies (k_inp, v_inp) source activations."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dt = cdtype(cfg)

    q = (x @ params["wq"].astype(dt))
    src = x if kv_override is None else kv_override
    k = (src @ params["wk"].astype(dt))
    v = (src @ params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, nq, hd)
    k = k.reshape(B, src.shape[1], nkv, hd)
    v = v.reshape(B, src.shape[1], nkv, hd)

    if kv_override is None:  # self-attention: rotate
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
        mask = None
        if causal:
            kpos = positions
            mask = _causal_mask(positions, kpos, window)
    else:
        mask = None  # cross-attention: full visibility

    if impl == "pallas" and kv_override is None and causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, window=window)
    elif impl == "flash" and kv_override is None and causal:
        out = flash_attention_xla(q, k, v, positions, positions, window=window)
    else:
        out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(hd))
    return out.reshape(B, S, nq * hd) @ params["wo"].astype(dt)


def attention_decode(cfg: ModelConfig, params, x, cache_k, cache_v, index, *,
                     ring=False):
    """One-token decode. x: (B, d). cache_k/v: (B, S, Hkv, hd).

    ``ring``: cache is a ring buffer (sliding window); index wraps.
    Returns (out (B, d), new_k, new_v).
    """
    B, d = x.shape
    S = cache_k.shape[1]
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dt = cdtype(cfg)

    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, 1, nq, hd)
    k = k.reshape(B, 1, nkv, hd)
    v = v.reshape(B, 1, nkv, hd)
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    q = apply_rope(cfg, q, pos)
    k = apply_rope(cfg, k, pos)

    slot = jnp.mod(index, S) if ring else index
    cache_k = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))

    kpos = jnp.arange(S, dtype=jnp.int32)
    if ring:
        valid = (kpos <= slot) | (index >= S)          # ring fully valid once wrapped
    else:
        valid = kpos <= index
    mask = jnp.broadcast_to(valid, (B, 1, S))

    out = _sdpa(q, cache_k.astype(dt), cache_v.astype(dt), mask, 1.0 / math.sqrt(hd))
    out = out.reshape(B, nq * hd) @ params["wo"].astype(dt)
    return out, cache_k, cache_v


def attention_cross_decode(cfg: ModelConfig, params, x, cross_k, cross_v):
    """Decode-time cross-attention against precomputed encoder KV."""
    B, d = x.shape
    hd = cfg.resolved_head_dim
    nq = cfg.num_heads
    dt = cdtype(cfg)
    q = (x @ params["wq"].astype(dt)).reshape(B, 1, nq, hd)
    out = _sdpa(q, cross_k.astype(dt), cross_v.astype(dt), None, 1.0 / math.sqrt(hd))
    return out.reshape(B, nq * hd) @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV with decode in compressed latent space
# ---------------------------------------------------------------------------
def init_mla(cfg: ModelConfig, rng):
    d = cfg.d_model
    H = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(rng, 7)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = _dense_init(ks[0], (d, cfg.q_lora_rank), pdtype(cfg))
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), pdtype(cfg))
        p["wq_b"] = _dense_init(ks[1], (cfg.q_lora_rank, H * qd), pdtype(cfg))
    else:
        p["wq"] = _dense_init(ks[0], (d, H * qd), pdtype(cfg))
    p["wkv_a"] = _dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), pdtype(cfg))
    p["kv_norm"] = jnp.ones((cfg.kv_lora_rank,), pdtype(cfg))
    # up-projections from latent: separate K-nope and V parts
    p["wk_b"] = _dense_init(ks[3], (cfg.kv_lora_rank, H * cfg.qk_nope_dim), pdtype(cfg))
    p["wv_b"] = _dense_init(ks[4], (cfg.kv_lora_rank, H * cfg.v_head_dim), pdtype(cfg))
    p["wo"] = _dense_init(ks[5], (H * cfg.v_head_dim, d), pdtype(cfg))
    return p


def _mla_q(cfg, params, x, dt):
    H = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        ql = x @ params["wq_a"].astype(dt)
        ql = rmsnorm(cfg, {"scale": params["q_norm"]}, ql)
        q = ql @ params["wq_b"].astype(dt)
    else:
        q = x @ params["wq"].astype(dt)
    return q.reshape(*x.shape[:-1], H, qd)


def mla_kv_latents(cfg: ModelConfig, params, x, positions):
    """(c_kv (B,S,rank), k_rope (B,S,rope)) — what MLA decode caches."""
    dt = cdtype(cfg)
    kv = x @ params["wkv_a"].astype(dt)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(cfg, {"scale": params["kv_norm"]}, c_kv)
    k_rope = apply_rope(cfg, k_rope[:, :, None, :], positions)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(cfg: ModelConfig, params, x, positions, *, impl="ref"):
    """Training/prefill MLA (expanded form)."""
    B, S, d = x.shape
    H = cfg.num_heads
    dt = cdtype(cfg)
    q = _mla_q(cfg, params, x, dt)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(cfg, q_rope, positions)

    kv = x @ params["wkv_a"].astype(dt)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(cfg, {"scale": params["kv_norm"]}, c_kv)
    k_rope = apply_rope(cfg, k_rope[:, :, None, :], positions)  # (B,S,1,rope)

    k_nope = (c_kv @ params["wk_b"].astype(dt)).reshape(B, S, H, cfg.qk_nope_dim)
    v = (c_kv @ params["wv_b"].astype(dt)).reshape(B, S, H, cfg.v_head_dim)

    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # NOTE: scale uses full qk dim; flash path rescales q so its internal
    # 1/sqrt(hd) matches.
    full_scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    if impl in ("flash", "pallas"):
        qd = q.shape[-1]
        q_scaled = q * (full_scale * math.sqrt(qd))
        out = flash_attention_xla(q_scaled, k, v, positions, positions)
    else:
        mask = _causal_mask(positions, positions, None)
        out = _sdpa(q, k, v, mask, full_scale)
    out = out.reshape(B, S, H * cfg.v_head_dim)
    return out @ params["wo"].astype(dt)


def mla_decode(cfg: ModelConfig, params, x, cache_ckv, cache_krope, index):
    """Absorbed-weight MLA decode: attention runs in the kv_lora latent space.

    cache_ckv: (B, S, kv_lora), cache_krope: (B, S, rope_dim).
    This is the MLA memory win: cache is (kv_lora + rope) per token instead of
    2 * H * head_dim.
    """
    B, d = x.shape
    H = cfg.num_heads
    dt = cdtype(cfg)
    S = cache_ckv.shape[1]

    q = _mla_q(cfg, params, x[:, None, :], dt)[:, 0]  # (B,H,qd)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    pos = jnp.full((B,), index, dtype=jnp.int32)
    q_rope = apply_rope(cfg, q_rope[:, None, :, :], pos[:, None])[:, 0]

    kv = x @ params["wkv_a"].astype(dt)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(cfg, {"scale": params["kv_norm"]}, c_kv)
    k_rope = apply_rope(cfg, k_rope[:, None, None, :], pos[:, None])[:, 0, 0]

    cache_ckv = lax.dynamic_update_slice(cache_ckv, c_kv[:, None].astype(cache_ckv.dtype), (0, index, 0))
    cache_krope = lax.dynamic_update_slice(cache_krope, k_rope[:, None].astype(cache_krope.dtype), (0, index, 0))

    # absorb wk_b into q: q_lat (B,H,kv_lora)
    wk_b = params["wk_b"].astype(dt).reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, wk_b)

    # decode softmax stays in the compute dtype with f32-ACCUMULATED
    # reductions: the (B, H, S) score tensor is the decode memory bottleneck
    # (8.4 GB/layer/device at 32k cache, batch 128 — EXPERIMENTS §Perf B it3);
    # an f32 copy doubles it, while dtype-accumulated reduces fuse the convert.
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat, cache_ckv.astype(dt))
              + jnp.einsum("bhr,bsr->bhs", q_rope, cache_krope.astype(dt)))
    logits = logits * jnp.asarray(scale, dt)
    valid = jnp.arange(S) <= index
    logits = jnp.where(valid[None, None, :], logits, jnp.asarray(-3e4, dt))
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
    w = (p / l.astype(dt))

    ctx_lat = jnp.einsum("bhs,bsr->bhr", w, cache_ckv.astype(dt))   # (B,H,kv_lora)
    wv_b = params["wv_b"].astype(dt).reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
    ctx = jnp.einsum("bhr,rhd->bhd", ctx_lat, wv_b).reshape(B, H * cfg.v_head_dim)
    return ctx @ params["wo"].astype(dt), cache_ckv, cache_krope


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, rng, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "w_up": _dense_init(ks[0], (d, ff), pdtype(cfg)),
        "w_down": _dense_init(ks[1], (ff, d), pdtype(cfg)),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _dense_init(ks[2], (d, ff), pdtype(cfg))
    return p


def mlp(cfg: ModelConfig, params, x):
    dt = cdtype(cfg)
    up = x @ params["w_up"].astype(dt)
    if cfg.gated_mlp:
        up = jax.nn.silu(x @ params["w_gate"].astype(dt)) * up
    else:
        up = jax.nn.gelu(up)
    return up @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based einsum dispatch; sort-based variant in
# repro.models.moe_sort used by the perf pass)
# ---------------------------------------------------------------------------
def init_moe(cfg: ModelConfig, rng):
    d = cfg.d_model
    ff = cfg.resolved_moe_d_ff
    E = cfg.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), pdtype(cfg), scale=0.02),
        "w_gate": _dense_init(ks[1], (E, d, ff), pdtype(cfg)),
        "w_up": _dense_init(ks[2], (E, d, ff), pdtype(cfg)),
        "w_down": _dense_init(ks[3], (E, ff, d), pdtype(cfg)),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=ff * cfg.num_shared_experts)
    return p


def moe_router(cfg: ModelConfig, params, x):
    """Returns (combine (T,E) float weights, aux_loss scalar). x: (T, d)."""
    logits = (x @ params["router"].astype(cdtype(cfg))).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = lax.top_k(probs, cfg.experts_per_token)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)
    combine = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], idx
    ].set(vals)
    # load-balance aux loss (Switch): E * sum_e (frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean((combine > 0).astype(jnp.float32), axis=0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return combine, aux


def _expert_ffn(cfg, params, ex_in):
    """ex_in: (G, E, cap, d) -> (G, E, cap, d), expert-parallel on the mesh.

    Sharding constraints force the GShard all-to-all: dispatch buffers arrive
    group-sharded (data axis), compute happens expert-sharded (model axis).
    """
    from repro.distributed.sharding import maybe_constraint
    dt = ex_in.dtype
    ex_in = maybe_constraint(ex_in, (None, "model", None, None))
    h = jnp.einsum("gecd,edf->gecf", ex_in, params["w_up"].astype(dt))
    g = jnp.einsum("gecd,edf->gecf", ex_in, params["w_gate"].astype(dt))
    ex_out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * h,
                        params["w_down"].astype(dt))
    return maybe_constraint(ex_out, ("data", None, None, None))


def moe(cfg: ModelConfig, params, x, *, capacity_factor=None, impl="einsum"):
    """x: (B, S, d) -> (B, S, d), aux_loss.

    GShard-style group-wise dispatch: tokens are split into ``cfg.moe_groups``
    groups (aligned with the data mesh axis); capacity is per group, so the
    dispatch tensors stay linear in the per-group token count.
    """
    B, S, d = x.shape
    dt = cdtype(cfg)
    T = B * S
    E = cfg.num_experts
    G = min(cfg.moe_groups, T)
    if T % G:
        G = 1
    Tg = T // G
    xt = x.reshape(T, d)

    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    combine, aux = moe_router(cfg, params, xt)                  # (T,E) fp32
    xg = xt.reshape(G, Tg, d)
    cg = combine.reshape(G, Tg, E).astype(dt)
    cap = max(int(Tg * cfg.experts_per_token / E * capacity_factor), 4)

    if impl == "sort":
        out = _moe_sort_grouped(cfg, params, xg, cg, cap).reshape(T, d)
    else:
        # position of each token within its expert queue, per group
        sel = (cg > 0)
        pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1     # (G,Tg,E)
        keep = sel & (pos < cap)
        disp = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=dt)
        disp = disp * keep[..., None].astype(dt)                # (G,Tg,E,cap)
        ex_in = jnp.einsum("gtec,gtd->gecd", disp, xg)
        ex_out = _expert_ffn(cfg, params, ex_in)
        w = disp * cg[..., None]
        out = jnp.einsum("gtec,gecd->gtd", w, ex_out).reshape(T, d)

    if cfg.num_shared_experts:
        out = out + mlp(cfg, params["shared"], xt)
    return out.reshape(B, S, d), aux


def _moe_sort_grouped(cfg, params, xg, cg, cap):
    from repro.models.moe_sort import moe_sort_dispatch_group, moe_sort_combine
    ex_in, info = jax.vmap(
        lambda xs, cs: moe_sort_dispatch_group(cfg, xs, cs, cap)
    )(xg, cg)
    ex_out = _expert_ffn(cfg, params, ex_in)
    return jax.vmap(
        lambda eo, xs, inf: moe_sort_combine(cfg, eo, xs.shape[0], inf)
    )(ex_out, xg, info)


# ---------------------------------------------------------------------------
# Mamba block (selective scan, chunked for TPU memory hierarchy)
# ---------------------------------------------------------------------------
def init_mamba(cfg: ModelConfig, rng):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.d_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), pdtype(cfg)),
        "conv_w": _dense_init(ks[1], (cfg.conv_kernel, di), pdtype(cfg), scale=0.5),
        "conv_b": jnp.zeros((di,), pdtype(cfg)),
        "x_proj": _dense_init(ks[2], (di, dt_rank + 2 * ds), pdtype(cfg)),
        "dt_proj": _dense_init(ks[3], (dt_rank, di), pdtype(cfg)),
        "dt_bias": jnp.full((di,), math.log(math.e - 1), pdtype(cfg)),  # softplus^-1(1)~
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(pdtype(cfg)),
        "D": jnp.ones((di,), pdtype(cfg)),
        "out_proj": _dense_init(ks[4], (di, d), pdtype(cfg)),
    }


def _mamba_gates(cfg, params, u, dt_):
    """u: (..., di). Returns dt (softplus), B_, C_ from x_proj."""
    dt_rank = max(cfg.d_model // 16, 1)
    ds = cfg.d_state
    proj = u @ params["x_proj"].astype(dt_)
    dtr, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dtr @ params["dt_proj"].astype(dt_) + params["dt_bias"].astype(dt_))
    return dt, B_, C_


def mamba(cfg: ModelConfig, params, x, *, chunk=256, return_state=False):
    """Training/prefill selective scan. x: (B,S,d)."""
    B, S, d = x.shape
    dt_ = cdtype(cfg)
    di = cfg.mamba_expand * d
    ds = cfg.d_state
    K = cfg.conv_kernel

    xz = x @ params["in_proj"].astype(dt_)
    u, z = jnp.split(xz, 2, axis=-1)                   # (B,S,di)

    # depthwise causal conv along S
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    conv_w = params["conv_w"].astype(dt_)              # (K, di)
    u = sum(pad[:, i:i + S, :] * conv_w[i] for i in range(K)) + params["conv_b"].astype(dt_)
    u = jax.nn.silu(u)

    dt, B_, C_ = _mamba_gates(cfg, params, u, dt_)     # dt:(B,S,di) B_,C_:(B,S,ds)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (di,ds)

    # chunked linear recurrence h_t = a_t * h_{t-1} + b_t
    nchunks = max(S // chunk, 1)
    Lc = S // nchunks if S % nchunks == 0 else S       # fall back to one chunk
    if S % max(nchunks, 1) != 0:
        nchunks, Lc = 1, S

    def chunk_body(h0, inp):
        dt_c, B_c, C_c, u_c = inp                      # (Lc, B, ...)
        a = jnp.exp(dt_c.astype(jnp.float32)[..., None] * A)          # (Lc,B,di,ds)
        b = (dt_c.astype(jnp.float32) * u_c.astype(jnp.float32))[..., None] * B_c.astype(jnp.float32)[..., None, :]
        # include carry as first element: h_t = (prod a) h0 + scan(b)
        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_s, b_s = lax.associative_scan(comb, (a, b), axis=0)
        h = a_s * h0[None] + b_s                       # (Lc,B,di,ds)
        y = jnp.einsum("lbds,lbs->lbd", h, C_c.astype(jnp.float32))
        return h[-1], y

    from repro.distributed.sharding import maybe_constraint
    perm = lambda t: t.reshape(B, nchunks, Lc, *t.shape[2:]).transpose(1, 2, 0, *range(3, t.ndim + 1))
    dt_ch, B_ch, C_ch, u_ch = (perm(t) for t in (dt, B_, C_, u))      # (nc,Lc,B,...)
    h0 = maybe_constraint(jnp.zeros((B, di, ds), jnp.float32),
                          (("pod", "data"), "model", None))
    dt_ch = maybe_constraint(dt_ch, (None, None, ("pod", "data"), "model"))
    u_ch = maybe_constraint(u_ch, (None, None, ("pod", "data"), "model"))
    h_final, ys = lax.scan(chunk_body, h0, (dt_ch, B_ch, C_ch, u_ch))
    y = ys.transpose(2, 0, 1, 3).reshape(B, S, di).astype(dt_)

    y = y + u * params["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    if return_state:
        # conv state: last K-1 pre-conv inputs (pre-activation u from in_proj)
        u_pre = jnp.split(x @ params["in_proj"].astype(dt_), 2, axis=-1)[0]
        conv_state = u_pre[:, S - (K - 1):, :]
        return out, (conv_state, h_final)
    return out


def mamba_decode(cfg: ModelConfig, params, x, conv_state, ssm_state):
    """One-token decode. x: (B,d); conv_state: (B,K-1,di); ssm_state: (B,di,ds)."""
    dt_ = cdtype(cfg)
    K = cfg.conv_kernel

    xz = x @ params["in_proj"].astype(dt_)
    u, z = jnp.split(xz, 2, axis=-1)                   # (B,di)

    window = jnp.concatenate([conv_state.astype(dt_), u[:, None, :]], axis=1)  # (B,K,di)
    conv_w = params["conv_w"].astype(dt_)
    u_c = jnp.einsum("bkd,kd->bd", window, conv_w) + params["conv_b"].astype(dt_)
    u_c = jax.nn.silu(u_c)
    new_conv_state = window[:, 1:, :].astype(conv_state.dtype)

    dt, B_, C_ = _mamba_gates(cfg, params, u_c, dt_)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)               # (B,di,ds)
    b = (dt.astype(jnp.float32) * u_c.astype(jnp.float32))[..., None] * B_.astype(jnp.float32)[:, None, :]
    h = a * ssm_state.astype(jnp.float32) + b
    y = jnp.einsum("bds,bs->bd", h, C_.astype(jnp.float32)).astype(dt_)
    y = y + u_c * params["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"].astype(dt_), new_conv_state, h.astype(ssm_state.dtype)
