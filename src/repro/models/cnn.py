"""The paper's anomaly-detection CNN (§V-B), in JAX.

Two 1D-CNN layers (128/256 filters, kernel 3, ReLU), flatten, dense 256
(ReLU), dropout 0.1, dense softmax over 9 classes, on 78-dim CIC-IDS-2017
feature vectors (treated as a length-78 sequence with 1 channel, as the
Keras original does).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.feds3a_cnn import CNNConfig


def init_cnn(cfg: CNNConfig, rng):
    ks = jax.random.split(rng, 5)
    f1, f2 = cfg.conv_filters
    K = cfg.conv_kernel
    n = cfg.num_features
    flat = n * f2

    def he(rng, shape, fan_in):
        return (jax.random.normal(rng, shape) * math.sqrt(2.0 / fan_in)
                ).astype(jnp.float32)

    return {
        "conv1_w": he(ks[0], (K, 1, f1), K),
        "conv1_b": jnp.zeros((f1,), jnp.float32),
        "conv2_w": he(ks[1], (K, f1, f2), K * f1),
        "conv2_b": jnp.zeros((f2,), jnp.float32),
        "dense_w": he(ks[2], (flat, cfg.hidden), flat),
        "dense_b": jnp.zeros((cfg.hidden,), jnp.float32),
        "out_w": he(ks[3], (cfg.hidden, cfg.num_classes), cfg.hidden),
        "out_b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }


def cnn_param_count(cfg: CNNConfig) -> int:
    """Total parameter count of the CNN (shape math only, no allocation)."""
    f1, f2 = cfg.conv_filters
    K, n, h, c = cfg.conv_kernel, cfg.num_features, cfg.hidden, cfg.num_classes
    return (K * 1 * f1 + f1) + (K * f1 * f2 + f2) + \
        (n * f2 * h + h) + (h * c + c)


def _conv1d(x, w, b):
    """x: (B, L, Cin); w: (K, Cin, Cout). SAME padding.

    im2col + matmul instead of lax.conv: identical math, but XLA:CPU lowers
    convolutions inside while loops (our per-epoch lax.scan) to a slow generic
    path (~60x measured), while dots stay fast — and on TPU the matmul form
    feeds the MXU directly.
    """
    K = w.shape[0]
    lo = (K - 1) // 2
    hi = K - 1 - lo
    xp = jnp.pad(x, ((0, 0), (lo, hi), (0, 0)))
    cols = jnp.stack([xp[:, i:i + x.shape[1], :] for i in range(K)], axis=2)
    B, L = x.shape[0], x.shape[1]
    out = cols.reshape(B, L, -1) @ w.reshape(-1, w.shape[2])
    return out + b


def cnn_forward(cfg: CNNConfig, params, x, *, train=False, rng=None):
    """x: (B, num_features) -> logits (B, num_classes)."""
    h = x[..., None]                                  # (B, 78, 1)
    h = jax.nn.relu(_conv1d(h, params["conv1_w"], params["conv1_b"]))
    h = jax.nn.relu(_conv1d(h, params["conv2_w"], params["conv2_b"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense_w"] + params["dense_b"])
    if train and rng is not None and cfg.dropout > 0:
        keep = 1.0 - cfg.dropout
        h = h * jax.random.bernoulli(rng, keep, h.shape) / keep
    return h @ params["out_w"] + params["out_b"]
