"""Full model assembly: embeddings, frontend stubs, scan-over-layers, heads.

All 10 assigned architectures are instances of this module with different
:class:`ModelConfig`. Layer parameters for structurally-identical layers are
stacked and executed with ``lax.scan`` (keeps HLO small and compile times sane
for 95-layer models); structurally-irregular prefixes are unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ATTN, ModelConfig
from repro.models import blocks as B
from repro.models import layers as L


# ---------------------------------------------------------------------------
# scan planning
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def scan_plan(cfg: ModelConfig):
    """Return (prefix_len, period, reps) maximizing scanned repetitions."""
    sigs = cfg.layer_pattern()
    n = len(sigs)
    best = (0, 1, 0)  # prefix, period, reps
    best_score = (-1, 0, 0)
    for prefix in range(n + 1):
        rem = n - prefix
        if rem == 0:
            continue
        for period in range(1, rem + 1):
            if rem % period:
                continue
            if all(sigs[i] == sigs[i + period] for i in range(prefix, n - period)):
                reps = rem // period
                score = (reps, -prefix, -period)
                if score > best_score:
                    best_score = score
                    best = (prefix, period, reps)
                break  # smallest valid period for this prefix is optimal
    prefix, period, reps = best
    if reps < 2:  # not worth scanning; unroll everything
        return n, 1, 0
    return prefix, period, reps


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, rng):
    sigs = cfg.layer_pattern()
    prefix_len, period, reps = scan_plan(cfg)
    keys = jax.random.split(rng, 8)
    d = cfg.d_model
    cross = cfg.is_encoder_decoder

    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, d)) * 0.02
                  ).astype(L.pdtype(cfg)),
        "final_norm": L.init_rmsnorm(cfg, keys[1]),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(keys[2], (d, cfg.vocab_size), L.pdtype(cfg))
    if cfg.num_vision_patches:
        params["vision_proj"] = L._dense_init(keys[3], (d, d), L.pdtype(cfg))

    pk = jax.random.split(keys[4], max(prefix_len, 1))
    params["prefix"] = [
        B.init_block(cfg, pk[i], sigs[i], cross_attn=cross) for i in range(prefix_len)
    ]
    if reps:
        params["scan"] = {}
        for j in range(period):
            sig = sigs[prefix_len + j]
            rk = jax.random.split(jax.random.fold_in(keys[5], j), reps)
            params["scan"][f"pos_{j}"] = jax.vmap(
                lambda r: B.init_block(cfg, r, sig, cross_attn=cross)
            )(rk)

    if cfg.is_encoder_decoder:
        ek = jax.random.split(keys[6], 4)
        enc_sig = (ATTN, False)
        erk = jax.random.split(ek[0], cfg.encoder_layers)
        params["encoder"] = {
            "pos": (jax.random.normal(ek[1], (cfg.num_encoder_positions, d)) * 0.02
                    ).astype(L.pdtype(cfg)),
            "scan": jax.vmap(lambda r: B.init_block(cfg, r, enc_sig))(erk),
            "norm": L.init_rmsnorm(cfg, ek[2]),
        }
    return params


# ---------------------------------------------------------------------------
# encoder (whisper stub-frontend)
# ---------------------------------------------------------------------------
def encode(cfg: ModelConfig, params, frames, *, remat=True):
    """frames: (B, F, d) precomputed conv/mel embeddings (frontend stub)."""
    dt = L.cdtype(cfg)
    x = frames.astype(dt) + params["encoder"]["pos"].astype(dt)[None, :frames.shape[1]]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])
    sig = (ATTN, False)

    def body(x, blk):
        x, _, _ = B.apply_block(cfg, blk, sig, x, positions, causal=False)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["encoder"]["scan"])
    return L.rmsnorm(cfg, params["encoder"]["norm"], x)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch, *, window=None, impl="ref",
            moe_impl="einsum", remat=True, collect_cache=False,
            seq_parallel=False, head_mode="full"):
    """batch: {"tokens": (B,S) int32, optional "frames": (B,F,d),
    "patches": (B,P,d)}. Returns (logits fp32, aux, caches|None).
    ``seq_parallel``: constrain activations to (batch, "model", None) between
    blocks so remat-saved tensors are sharded over the model axis too.
    ``head_mode``: "full" logits (B,S,V) or "last" logits (B,V)."""
    from repro.distributed.sharding import maybe_constraint
    sigs = cfg.layer_pattern()
    prefix_len, period, reps = scan_plan(cfg)
    dt = L.cdtype(cfg)
    sp = (lambda t: maybe_constraint(t, (("pod", "data"), "model", None))) \
        if seq_parallel else (lambda t: t)

    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]
    if cfg.num_vision_patches and "patches" in batch:
        patches = batch["patches"].astype(dt) @ params["vision_proj"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
    Bsz, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (Bsz, S))

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(cfg, params, batch["frames"], remat=remat)

    aux = jnp.zeros((), jnp.float32)
    caches = {"prefix": [], "scan": {}} if collect_cache else None

    x = sp(x)
    for i in range(prefix_len):
        x, a, c = B.apply_block(cfg, params["prefix"][i], sigs[i], x, positions,
                                enc_out=enc_out, window=window, impl=impl,
                                moe_impl=moe_impl, collect_cache=collect_cache)
        x = sp(x)
        aux = aux + a
        if collect_cache:
            caches["prefix"].append(c)

    if reps:
        def body(carry, per_rep):
            x, aux = carry
            reps_cache = {}
            for j in range(period):
                sig = sigs[prefix_len + j]
                x, a, c = B.apply_block(cfg, per_rep[f"pos_{j}"], sig, x, positions,
                                        enc_out=enc_out, window=window, impl=impl,
                                        moe_impl=moe_impl,
                                        collect_cache=collect_cache)
                x = sp(x)
                aux = aux + a
                if collect_cache:
                    reps_cache[f"pos_{j}"] = c
            return (x, aux), (reps_cache if collect_cache else None)

        if remat:
            body = jax.checkpoint(body)
        (x, aux), ys = lax.scan(body, (x, aux), params["scan"])
        if collect_cache:
            caches["scan"] = ys

    x = L.rmsnorm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if head_mode == "last":
        x = x[:, -1]
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    return logits, aux, caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size, cache_len, *, dtype=None):
    """Zeroed decode cache for the whole model (prefix list + scan stacks)."""
    sigs = cfg.layer_pattern()
    prefix_len, period, reps = scan_plan(cfg)
    cross = cfg.num_encoder_positions if cfg.is_encoder_decoder else 0
    cache = {
        "prefix": [
            B.init_block_cache(cfg, sigs[i], batch_size, cache_len,
                               cross_len=cross, dtype=dtype)
            for i in range(prefix_len)
        ],
        "scan": {},
    }
    for j in range(period if reps else 0):
        sig = sigs[prefix_len + j]
        one = B.init_block_cache(cfg, sig, batch_size, cache_len,
                                 cross_len=cross, dtype=dtype)
        cache["scan"][f"pos_{j}"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (reps,) + t.shape), one)
    return cache


def decode_step(cfg: ModelConfig, params, token, cache, index, *, ring=False,
                moe_impl="einsum"):
    """token: (B,) int32; index: scalar int32 position. -> (logits (B,V), cache)."""
    sigs = cfg.layer_pattern()
    prefix_len, period, reps = scan_plan(cfg)
    dt = L.cdtype(cfg)

    x = params["embed"].astype(dt)[token]

    new_prefix = []
    for i in range(prefix_len):
        x, c = B.apply_block_decode(cfg, params["prefix"][i], sigs[i], x,
                                    cache["prefix"][i], index, ring=ring,
                                    moe_impl=moe_impl)
        new_prefix.append(c)

    new_scan = cache["scan"]
    if reps:
        def body(x, xs):
            per_rep, per_cache = xs
            out_cache = {}
            for j in range(period):
                sig = sigs[prefix_len + j]
                x, c = B.apply_block_decode(cfg, per_rep[f"pos_{j}"], sig, x,
                                            per_cache[f"pos_{j}"], index,
                                            ring=ring, moe_impl=moe_impl)
                out_cache[f"pos_{j}"] = c
            return x, out_cache

        x, new_scan = lax.scan(body, x, (params["scan"], cache["scan"]))

    x = L.rmsnorm(cfg, params["final_norm"], x[:, None, :])[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    return logits, {"prefix": new_prefix, "scan": new_scan}


def prefill(cfg: ModelConfig, params, batch, cache_len, *, window=None,
            impl="ref", moe_impl="einsum"):
    """Run the prompt and build a decode cache. Returns (last_logits, cache)."""
    logits, _, caches = forward(cfg, params, batch, window=window, impl=impl,
                                moe_impl=moe_impl, remat=False, collect_cache=True)

    def pad_seq(t, target, axis=1):
        if t.ndim > axis and t.shape[axis] < target and t.ndim >= 3:
            padw = [(0, 0)] * t.ndim
            padw[axis] = (0, target - t.shape[axis])
            return jnp.pad(t, padw)
        return t

    def fix(c):
        out = {}
        for k, v in c.items():
            if k in ("k", "v", "ckv", "krope"):
                out[k] = pad_seq(v, cache_len, axis=v.ndim - 3 if k in ("k", "v") else v.ndim - 2)
            else:
                out[k] = v
        return out

    def fix_stacked(c):
        out = {}
        for k, v in c.items():
            if k in ("k", "v", "ckv", "krope"):
                axis = v.ndim - 3 if k in ("k", "v") else v.ndim - 2
                out[k] = pad_seq(v, cache_len, axis=axis)
            else:
                out[k] = v
        return out

    cache = {
        "prefix": [fix(c) for c in caches["prefix"]],
        "scan": {k: fix_stacked(v) for k, v in caches["scan"].items()},
    }
    if cfg.is_encoder_decoder:
        enc_out = encode(cfg, params, batch["frames"], remat=False)
        # precompute cross KV for every decoder layer
        sigs = cfg.layer_pattern()
        prefix_len, period, reps = scan_plan(cfg)
        pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]), enc_out.shape[:2])
        for i in range(prefix_len):
            kv = B._attn_kv(cfg, params["prefix"][i]["xattn"], enc_out, pos,
                            rotate=False)
            cache["prefix"][i]["cross_k"] = kv["k"]
            cache["prefix"][i]["cross_v"] = kv["v"]
        for j in range(period if reps else 0):
            blks = params["scan"][f"pos_{j}"]
            kv = jax.vmap(lambda blk: B._attn_kv(cfg, blk["xattn"], enc_out,
                                                 pos, rotate=False))(blks)
            cache["scan"][f"pos_{j}"]["cross_k"] = kv["k"]
            cache["scan"][f"pos_{j}"]["cross_v"] = kv["v"]
    return logits[:, -1], cache
