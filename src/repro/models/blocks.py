"""Residual blocks: init / forward / decode, dispatched on the layer signature
``(kind, is_moe)`` from ``ModelConfig.layer_pattern()``.

Block anatomy:
  ATTN  : x + attn(ln1(x));  x + {mlp|moe}(ln2(x))   (mla when cfg.mla)
  MAMBA : x + mamba(ln1(x)); x + {mlp|moe}(ln2(x))   (jamba-style)
  MLSTM : x + mlstm(ln1(x))                           (FFN inside the block)
  SLSTM : x + slstm(ln1(x))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.models import layers as L
from repro.models import xlstm as X


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_block(cfg: ModelConfig, rng, sig, *, cross_attn=False):
    kind, is_moe = sig
    ks = jax.random.split(rng, 6)
    p = {"ln1": L.init_rmsnorm(cfg, ks[0])}
    if kind == ATTN:
        p["attn"] = L.init_mla(cfg, ks[1]) if cfg.mla else L.init_attention(cfg, ks[1])
    elif kind == MAMBA:
        p["mamba"] = L.init_mamba(cfg, ks[1])
    elif kind == MLSTM:
        p["cell"] = X.init_mlstm(cfg, ks[1])
        return p
    elif kind == SLSTM:
        p["cell"] = X.init_slstm(cfg, ks[1])
        return p
    if cross_attn:
        p["ln_x"] = L.init_rmsnorm(cfg, ks[4])
        p["xattn"] = L.init_attention(cfg, ks[5])
    if is_moe:
        p["ln2"] = L.init_rmsnorm(cfg, ks[2])
        p["moe"] = L.init_moe(cfg, ks[3])
    elif cfg.d_ff:
        p["ln2"] = L.init_rmsnorm(cfg, ks[2])
        p["mlp"] = L.init_mlp(cfg, ks[3])
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def apply_block(cfg: ModelConfig, params, sig, x, positions, *, enc_out=None,
                window=None, impl="ref", moe_impl="einsum", collect_cache=False,
                causal=True):
    """Returns (x, aux_loss, cache_or_None).

    ``collect_cache``: capture per-layer decode state during prefill.
    """
    kind, is_moe = sig
    aux = jnp.zeros((), jnp.float32)
    cache = None

    h = L.rmsnorm(cfg, params["ln1"], x)
    if kind == ATTN:
        if cfg.mla:
            a = L.mla_attention(cfg, params["attn"], h, positions, impl=impl)
            if collect_cache:
                ckv, krope = L.mla_kv_latents(cfg, params["attn"], h, positions)
                cache = {"ckv": ckv, "krope": krope}
        else:
            a = L.attention(cfg, params["attn"], h, positions, window=window,
                            impl=impl, causal=causal)
            if collect_cache:
                cache = _attn_kv(cfg, params["attn"], h, positions)
        x = x + a
    elif kind == MAMBA:
        if collect_cache:
            a, (conv, ssm) = L.mamba(cfg, params["mamba"], h, return_state=True)
            cache = {"conv": conv, "ssm": ssm}
        else:
            a = L.mamba(cfg, params["mamba"], h)
        x = x + a
    elif kind == MLSTM:
        uz = h @ params["cell"]["up"].astype(h.dtype)
        u, z = jnp.split(uz, 2, axis=-1)
        q, k, v, ir, fr = X._mlstm_qkvif(cfg, params["cell"], u)
        hh, state = X.mlstm_cell_chunked(q, k, v, ir, fr)
        hh = hh.reshape(x.shape[0], x.shape[1], -1).astype(h.dtype) * jax.nn.silu(z)
        x = x + hh @ params["cell"]["down"].astype(h.dtype)
        if collect_cache:
            cache = {"C": state[0], "n": state[1], "m": state[2]}
    elif kind == SLSTM:
        if collect_cache:
            out, st = X.slstm(cfg, params["cell"], h, return_state=True)
            cache = {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
            x = x + out
        else:
            x = x + X.slstm(cfg, params["cell"], h)

    if "xattn" in params and enc_out is not None:
        h = L.rmsnorm(cfg, params["ln_x"], x)
        x = x + L.attention(cfg, params["xattn"], h, positions,
                            kv_override=enc_out, causal=False)

    if is_moe:
        h = L.rmsnorm(cfg, params["ln2"], x)
        m, a_loss = L.moe(cfg, params["moe"], h, impl=moe_impl)
        x = x + m
        aux = aux + a_loss
    elif "mlp" in params:
        h = L.rmsnorm(cfg, params["ln2"], x)
        x = x + L.mlp(cfg, params["mlp"], h)
    return x, aux, cache


def _attn_kv(cfg, attn_params, h, positions, *, rotate=True):
    """Recompute K/V for cache capture during prefill. ``rotate=False`` for
    cross-attention (rope-free, matching the kv_override forward path)."""
    B, S, _ = h.shape
    dt = h.dtype
    hd = cfg.resolved_head_dim
    k = (h @ attn_params["wk"].astype(dt))
    v = (h @ attn_params["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + attn_params["bk"].astype(dt)
        v = v + attn_params["bv"].astype(dt)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if rotate:
        k = L.apply_rope(cfg, k, positions)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# decode (single token)
# ---------------------------------------------------------------------------
def apply_block_decode(cfg: ModelConfig, params, sig, x, cache, index, *,
                       ring=False, moe_impl="einsum"):
    """x: (B, d). cache: this block's state pytree. Returns (x, new_cache)."""
    kind, is_moe = sig

    h = L.rmsnorm(cfg, params["ln1"], x[:, None, :])[:, 0]
    if kind == ATTN:
        if cfg.mla:
            a, ckv, krope = L.mla_decode(cfg, params["attn"], h,
                                         cache["ckv"], cache["krope"], index)
            cache = dict(cache, ckv=ckv, krope=krope)
        else:
            a, k, v = L.attention_decode(cfg, params["attn"], h,
                                         cache["k"], cache["v"], index, ring=ring)
            cache = dict(cache, k=k, v=v)
        x = x + a
    elif kind == MAMBA:
        a, conv, ssm = L.mamba_decode(cfg, params["mamba"], h,
                                      cache["conv"], cache["ssm"])
        cache = dict(cache, conv=conv, ssm=ssm)
        x = x + a
    elif kind == MLSTM:
        a, state = X.mlstm_decode(cfg, params["cell"], h,
                                  (cache["C"], cache["n"], cache["m"]))
        cache = dict(cache, C=state[0], n=state[1], m=state[2])
        x = x + a
    elif kind == SLSTM:
        a, state = X.slstm_decode(cfg, params["cell"], h,
                                  (cache["c"], cache["n"], cache["m"], cache["h"]))
        cache = dict(cache, c=state[0], n=state[1], m=state[2], h=state[3])
        x = x + a

    if "xattn" in params and "cross_k" in cache:
        h = L.rmsnorm(cfg, params["ln_x"], x[:, None, :])[:, 0]
        x = x + L.attention_cross_decode(cfg, params["xattn"], h,
                                         cache["cross_k"], cache["cross_v"])

    if is_moe:
        h = L.rmsnorm(cfg, params["ln2"], x[:, None, :])
        m, _ = L.moe(cfg, params["moe"], h, impl=moe_impl)
        x = x + m[:, 0]
    elif "mlp" in params:
        h = L.rmsnorm(cfg, params["ln2"], x[:, None, :])[:, 0]
        x = x + L.mlp(cfg, params["mlp"], h)
    return x, cache


# ---------------------------------------------------------------------------
# cache allocation
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ModelConfig, sig, batch, cache_len, *,
                     cross_len=0, dtype=None):
    """Zero decode-state for one block."""
    kind, _ = sig
    dt = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    c = {}
    if kind == ATTN:
        if cfg.mla:
            c["ckv"] = jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt)
            c["krope"] = jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dt)
        else:
            c["k"] = jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dt)
            c["v"] = jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dt)
    elif kind == MAMBA:
        di = cfg.mamba_expand * cfg.d_model
        c["conv"] = jnp.zeros((batch, cfg.conv_kernel - 1, di), dt)
        c["ssm"] = jnp.zeros((batch, di, cfg.d_state), jnp.float32)
    elif kind == MLSTM:
        C0, n0, m0 = X.init_mlstm_state(cfg, batch)
        c = {"C": C0, "n": n0, "m": m0}
    elif kind == SLSTM:
        s = X.init_slstm_state(cfg, batch)
        c = {"c": s[0], "n": s[1], "m": s[2], "h": s[3]}
    if cross_len:
        c["cross_k"] = jnp.zeros((batch, cross_len, cfg.num_kv_heads, hd), dt)
        c["cross_v"] = jnp.zeros((batch, cross_len, cfg.num_kv_heads, hd), dt)
    return c
