"""Sort-based MoE dispatch (per token group).

Alternative to the GShard one-hot einsum dispatch in ``layers.moe``: tokens are
argsorted by expert id and scattered into a compact (E, cap, d) buffer, so the
O(Tg*E*cap*d) dispatch einsum FLOPs disappear (replaced by gathers/scatters).
Used by the perf pass (EXPERIMENTS.md §Perf) — for deepseek-v2 (160 experts)
the einsum dispatch FLOPs rival the expert FLOPs themselves.

Functions here operate on ONE group; ``layers._moe_sort_grouped`` vmaps them
over the group axis, which keeps the group axis shardable on the data mesh
axis (every op is batched, so GSPMD partitions it cleanly).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def moe_sort_dispatch_group(cfg, xs, cs, cap):
    """xs: (Tg, d); cs: (Tg, E) combine weights (top-k nonzero).

    Returns (ex_in (E, cap, d), info) where info carries the scatter plan.
    """
    Tg, d = xs.shape
    E = cfg.num_experts
    k = cfg.experts_per_token
    dt = xs.dtype

    vals, eidx = lax.top_k(cs, k)                            # (Tg,k)
    e_flat = eidx.reshape(-1)
    w_flat = vals.reshape(-1).astype(dt)
    t_flat = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)

    order = jnp.argsort(e_flat, stable=True)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]

    counts = jnp.bincount(e_s, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(Tg * k, dtype=jnp.int32) - offsets[e_s].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, e_s * cap + pos, E * cap)         # overflow -> trash row

    buf = jnp.zeros((E * cap + 1, d), dt).at[slot].set(xs[t_s])
    ex_in = buf[:-1].reshape(E, cap, d)
    return ex_in, (slot, t_s, w_s * keep.astype(dt))


def moe_sort_combine(cfg, ex_out, Tg, info):
    """ex_out: (E, cap, d) -> (Tg, d) weighted combine."""
    slot, t_s, w_s = info
    E_cap, d = ex_out.shape[0] * ex_out.shape[1], ex_out.shape[2]
    flat = jnp.concatenate([ex_out.reshape(E_cap, d),
                            jnp.zeros((1, d), ex_out.dtype)])
    y_assign = flat[slot] * w_s[:, None]
    return jnp.zeros((Tg, d), ex_out.dtype).at[t_s].add(y_assign)
