"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes
it useless for scan-over-layers / microbatch-accumulation programs (a 95-layer
scanned model reports ~1/95th of its FLOPs, and per-layer FSDP all-gathers
disappear from the collective totals).

This module parses ``compiled.as_text()`` (post-SPMD, post-optimization HLO),
builds the computation call graph (fusion ``calls=``, while ``body=`` /
``condition=`` with ``known_trip_count``, reduce ``to_apply=``, conditional
branches) and accumulates, per device:

  * dot FLOPs        2 * prod(output dims) * prod(contracting dims),
                     multiplied by enclosing trip counts (all call edges).
  * HBM bytes        per op call site: output + operand bytes with operands
                     capped at 4x output + 4KiB (a fusion that slices a big
                     stacked scan-weight buffer reads one slice, not the
                     buffer); dynamic-update-slice sites count 2x the update
                     slice (in-place semantics). Fusion *bodies* are NOT
                     recursed for bytes — intra-fusion intermediates live in
                     registers/VMEM. This is a deterministic HBM-traffic
                     ESTIMATE; its biases are consistent across program
                     variants, which is what the perf loop compares.
  * collective bytes output payload of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     multiplied by trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*[a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALLEE_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "iota",
    "replica-id", "bitcast-convert", "copy-start", "copy-done",
}


def _shape_elems_bytes(shape_str):
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


def _array_dims(shape_str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Comp:
    name: str
    flops: float = 0.0
    hbm: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    edges: list = field(default_factory=list)  # (callee, multiplier, is_fusion)


def _first_array_shape(shape_str):
    m = _SHAPE_RE.search(shape_str)
    return m.group(0) if m else ""


def _parse_computations(text):
    comps = {}
    cur = None
    symbols = {}
    for raw in text.splitlines():
        stripped = raw.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if (raw.startswith("%") or raw.startswith("ENTRY")) and stripped.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?(%[\w.\-]+)", stripped)
            cur = Comp(m.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                comps["__entry__"] = cur
            symbols = {}
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue

        m = _OP_RE.match(stripped)
        if not m:
            continue
        name, shape_str, opname, rest = m.groups()
        symbols[name] = shape_str

        base = opname[:-6] if opname.endswith("-start") else opname
        if base in COLLECTIVES:
            _, b = _shape_elems_bytes(shape_str)
            cur.coll[base] += b
            cur.hbm += 2 * b
            continue
        if opname.endswith("-done"):
            continue

        # --- call edges ---
        mult = 1
        tm = _TRIP_RE.search(stripped)
        if tm:
            mult = int(tm.group(1))
        is_fusion = opname == "fusion"
        for callee in _CALLEE_RE.findall(stripped):
            cur.edges.append((callee, mult, is_fusion))
        bm = _BRANCH_RE.search(stripped)
        if bm:
            for callee in bm.group(1).split(","):
                callee = callee.strip()
                if callee:
                    cur.edges.append((callee, 1, False))

        # --- dot flops ---
        if opname == "dot":
            out_dims = _array_dims(shape_str) or []
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            # operands may carry inline types (newer XLA: "dot(f32[a,b]{1,0}
            # %lhs, ...)") or be bare symbols (older: "dot(%lhs, %rhs)")
            om = re.match(
                r"\(?\s*(?:([a-z]+[0-9]*[a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)"
                r"\s+)?(%[\w.\-]+)", rest)
            if om and om.group(1):
                lhs_dims = _array_dims(om.group(1)) or []
            else:
                lhs_name = om.group(2) if om else ""
                lhs_dims = _array_dims(symbols.get(lhs_name, "")) or []
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", stripped)
            contract = 1
            if cm and lhs_dims:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            cur.flops += 2.0 * out_elems * contract
        elif opname == "convolution":
            out_dims = _array_dims(shape_str) or []
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            cur.flops += 2.0 * out_elems

        # --- HBM bytes (call-site model) ---
        if opname in _SKIP_BYTES_OPS:
            continue
        arglist = rest.split(")")[0]
        operand_names = re.findall(r"%[\w.\-]+", arglist)

        if opname == "dynamic-update-slice" or "dynamic-update-slice" in name:
            # in-place update: traffic ~ 2x the update slice(s)
            out_shape = _first_array_shape(shape_str)
            upd = 0
            for op_n in operand_names:
                s = symbols.get(op_n, "")
                if _first_array_shape(s) != out_shape:
                    _, b = _shape_elems_bytes(s)
                    upd += min(b, 4 * _shape_elems_bytes(shape_str)[1] + 4096)
            cur.hbm += 2 * upd if upd else 2 * _shape_elems_bytes(shape_str)[1]
            continue
        if opname == "dynamic-slice":
            _, ob = _shape_elems_bytes(shape_str)
            cur.hbm += 2 * ob
            continue

        _, ob = _shape_elems_bytes(shape_str)
        cap = None if opname in ("dot", "convolution") else 4 * ob + 4096
        ib = 0
        for op_n in operand_names:
            if op_n in symbols:
                _, b = _shape_elems_bytes(symbols[op_n])
                ib += b if cap is None else min(b, cap)
        cur.hbm += ob + ib
    return comps


def analyze_text(text):
    """Returns per-device flops, hbm_bytes, collective bytes by kind."""
    comps = _parse_computations(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    memo = {}

    def total(comp_name):
        if comp_name in memo:
            return memo[comp_name]
        comp = comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, {k: 0.0 for k in COLLECTIVES})
        memo[comp_name] = (0.0, 0.0, {k: 0.0 for k in COLLECTIVES})
        f, h = comp.flops, comp.hbm
        c = dict(comp.coll)
        for callee, mult, is_fusion in comp.edges:
            cf, ch, cc = total(callee)
            f += mult * cf
            if not is_fusion:      # fusion internals live in registers/VMEM
                h += mult * ch
            for k in COLLECTIVES:
                c[k] += mult * cc[k]
        memo[comp_name] = (f, h, c)
        return memo[comp_name]

    f, h, c = total(entry.name)
    return {
        "flops": f,
        "hbm_bytes": h,
        "collectives": c,
        "collective_bytes": sum(c.values()),
    }
