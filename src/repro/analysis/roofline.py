"""Roofline terms from a compiled dry-run artifact.

v5e hardware constants (per chip): 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s
per ICI link. The compiled module is post-SPMD, so FLOPs / bytes / collective
payloads parsed from it are PER-DEVICE quantities; the roofline terms below
are therefore directly "seconds per step on one chip", and the slowest term
is the projected bottleneck.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output payload bytes of every collective op, by op kind."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.:  %all-reduce.5 = bf16[1024,512]{1,0} all-reduce(...)
        m = re.match(r"%?[\w.\-]+ = (.*?) ([a-z\-]+)\(", line)
        if not m:
            continue
        shape_str, opname = m.groups()
        op = opname.rstrip("-start").rstrip("-done") if opname else opname
        for kind in _COLLECTIVES:
            if opname == kind or opname == kind + "-start":
                out[kind] += _shape_bytes(shape_str)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    name: str
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: float            # per device
    coll_breakdown: dict = field(default_factory=dict)
    model_flops: float = 0.0     # 6*N*D useful flops (global)
    chips: int = 256

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        total = self.flops * self.chips
        return (self.model_flops / total) if total else 0.0

    def row(self):
        return (f"{self.name:44s} {self.t_compute*1e3:10.2f} "
                f"{self.t_memory*1e3:10.2f} {self.t_collective*1e3:10.2f} "
                f"{self.bottleneck:10s} {self.useful_flops_ratio:8.3f}")


def analyze(name, compiled, *, model_flops=0.0, chips=256) -> Roofline:
    """Trip-count-aware HLO cost model (see hlo_cost.py) — XLA's own
    cost_analysis() counts while bodies once and is useless for scanned
    layers / microbatch accumulation."""
    from repro.analysis.hlo_cost import analyze_text
    r = analyze_text(compiled.as_text())
    coll = dict(r["collectives"])
    coll["total"] = r["collective_bytes"]
    return Roofline(name=name, flops=r["flops"], hbm_bytes=r["hbm_bytes"],
                    coll_bytes=r["collective_bytes"], coll_breakdown=coll,
                    model_flops=model_flops, chips=chips)


def model_flops_per_step(cfg, shape) -> float:
    """6 * N_active * tokens (train counts fwd+bwd; decode counts one token)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per seq
