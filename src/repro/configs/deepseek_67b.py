"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954].

95 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=102400.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    window=8192,              # sliding-window decode carve-in for long_500k
    source="arXiv:2401.02954",
))
