"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48 layers, d_model=5120, 40 heads (GQA kv=8), expert d_ff=8192, vocab=202048.
MoE every other layer (interleave step 2, as the Scout reference), 1 shared
expert; dense layers use d_ff=16384. Early-fusion vision stub: `input_specs`
can prepend patch embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=202048,
    head_dim=128,
    moe=True,
    num_experts=128,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    moe_layer_period=2,
    moe_layer_offset=1,
    num_vision_patches=576,    # early-fusion image tokens (stubbed projector)
    window=8192,               # llama4 uses chunked/sliding local attention; also long_500k carve-in
    rope_theta=5e5,
    opt_state_dtype="bfloat16",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
