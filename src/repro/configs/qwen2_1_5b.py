"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671].

28 layers, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    tie_embeddings=True,
    window=8192,              # sliding-window decode carve-in for long_500k
    rope_theta=1e6,
    source="arXiv:2407.10671",
))
