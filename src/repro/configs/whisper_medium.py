"""whisper-medium [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

24 decoder layers (+24 encoder layers), d_model=1024, 16 heads (MHA, kv=16),
d_ff=4096, vocab=51865. `input_specs()` supplies precomputed (B, 1500, d_model)
mel/conv frame embeddings for the encoder.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    encoder_layers=24,
    num_encoder_positions=1500,
    window=8192,              # sliding-window decode carve-in for long shapes
    gated_mlp=False,          # whisper uses plain GELU MLP
    tie_embeddings=True,
    source="arXiv:2212.04356",
))
