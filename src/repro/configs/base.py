"""Model / run configuration dataclasses and the architecture registry.

Every assigned architecture lives in its own ``src/repro/configs/<id>.py`` and
registers a full-size :class:`ModelConfig` plus a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


# ---------------------------------------------------------------------------
# Block kinds used by the layer-pattern machinery (hybrid archs).
# ---------------------------------------------------------------------------
ATTN = "attn"          # full (or sliding-window) self-attention + MLP
MAMBA = "mamba"        # mamba selective-scan block
MLSTM = "mlstm"        # xLSTM matrix-memory block (parallelizable)
SLSTM = "slstm"        # xLSTM scalar-memory block (recurrent)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None       # expert FFN width (defaults to d_ff)
    moe_layer_period: int = 1            # MoE every k-th layer (1 = all)
    moe_layer_offset: int = 0            # first MoE layer index mod period
    first_k_dense: int = 0               # deepseek: first k layers always dense
    router_aux_loss_coef: float = 0.01
    moe_groups: int = 1                  # GShard token groups (= data shards on mesh)
    moe_capacity_factor: float = 1.25    # expert capacity (tokens dropped beyond)

    # --- MLA (DeepSeek-V2) ---
    mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 = dense q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- hybrid / SSM ---
    attn_layer_period: int = 1           # jamba: 1 attention layer per 8
    attn_layer_offset: int = 0
    ssm_type: str = "none"               # none | mamba | xlstm
    d_state: int = 16
    conv_kernel: int = 4
    mamba_expand: int = 2
    slstm_period: int = 0                # xlstm: 1 sLSTM per k blocks (0 = none)
    slstm_offset: int = 7

    # --- encoder-decoder / multimodal frontends (stubs) ---
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    num_encoder_positions: int = 1500    # whisper audio frames after conv stub
    num_vision_patches: int = 0          # pixtral/llama4 patch embeddings prepended

    # --- attention details ---
    window: Optional[int] = None         # sliding-window width (None = full)
    qkv_bias: bool = False               # qwen2
    gated_mlp: bool = True               # SwiGLU (False: plain GELU MLP, whisper)
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- numerics ---
    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"     # bf16 for >=200B models

    # --- source citation ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def block_kind(self, layer_idx: int) -> str:
        """Which block type occupies layer ``layer_idx``."""
        if self.ssm_type == "xlstm":
            if self.slstm_period and layer_idx % self.slstm_period == self.slstm_offset:
                return SLSTM
            return MLSTM
        if self.ssm_type == "mamba":
            if layer_idx % self.attn_layer_period == self.attn_layer_offset:
                return ATTN
            return MAMBA
        return ATTN

    def is_moe_layer(self, layer_idx: int) -> bool:
        if not self.moe or layer_idx < self.first_k_dense:
            return False
        return layer_idx % self.moe_layer_period == self.moe_layer_offset

    def layer_pattern(self) -> tuple:
        """(block_kind, is_moe) per layer — the structural signature.

        Scan-over-layers stacks parameters for layers sharing a signature.
        """
        return tuple((self.block_kind(i), self.is_moe_layer(i)) for i in range(self.num_layers))

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        if self.is_encoder_decoder:
            total += self.num_encoder_positions * d      # encoder pos embed (stub side)

        def attn_params() -> int:
            if self.mla:
                qdim = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                p = d * qdim if not self.q_lora_rank else d * self.q_lora_rank + self.q_lora_rank * qdim
                p += d * (self.kv_lora_rank + self.qk_rope_dim)
                p += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                p += self.num_heads * self.v_head_dim * d
                return p
            return d * n_q + 2 * d * n_kv + n_q * d

        def mlp_params(ff: int) -> int:
            return (3 if self.gated_mlp else 2) * d * ff  # (gate,) up, down

        def mamba_params() -> int:
            dinner = self.mamba_expand * d
            p = d * 2 * dinner                           # in_proj (x, z)
            p += dinner * self.conv_kernel               # depthwise conv
            p += dinner * (self.d_state * 2 + 1)         # B, C, dt per channel-ish
            p += dinner * self.d_state                   # A
            p += dinner * d                              # out_proj
            return p

        def xlstm_params(kind: str) -> int:
            dinner = 2 * d
            p = d * 2 * dinner + dinner * d              # up (x,z) + down
            p += 3 * dinner * (1 if kind == MLSTM else dinner // max(self.num_heads, 1))
            if kind == MLSTM:
                p += 3 * dinner * self.resolved_head_dim  # qkv-ish small projections
            return p

        for i in range(self.num_layers):
            kind = self.block_kind(i)
            if kind == ATTN:
                total += attn_params()
                if self.is_moe_layer(i):
                    total += self.num_experts * mlp_params(self.resolved_moe_d_ff)
                    total += self.num_shared_experts * mlp_params(self.resolved_moe_d_ff)
                    total += d * self.num_experts        # router
                elif self.d_ff:
                    total += mlp_params(self.d_ff)
            elif kind == MAMBA:
                total += mamba_params()
                if self.is_moe_layer(i):
                    total += self.num_experts * mlp_params(self.resolved_moe_d_ff)
                    total += self.num_shared_experts * mlp_params(self.resolved_moe_d_ff)
                    total += d * self.num_experts
                elif self.d_ff:
                    total += mlp_params(self.d_ff)
            else:
                total += xlstm_params(kind)
            total += 2 * d                               # norms
        if self.is_encoder_decoder:
            # encoder blocks: self-attn + mlp
            total += self.encoder_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            # decoder cross-attention
            total += self.num_layers * (attn_params() + d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        full = dataclasses.replace(
            self,
            num_experts=self.experts_per_token,
            num_shared_experts=self.num_shared_experts,
        )
        return full.param_count()

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 layers, small dims, <=4 experts."""
        def rd(v, cap):
            return min(v, cap) if v else v
        base = dict(
            name=self.name + "-smoke",
            num_layers=2 if self.ssm_type != "mamba" else max(2, self.attn_layer_period),
            d_model=rd(self.d_model, 256),
            num_heads=rd(self.num_heads, 4),
            num_kv_heads=rd(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=rd(self.d_ff, 512),
            vocab_size=rd(self.vocab_size, 512),
            num_experts=rd(self.num_experts, 4),
            experts_per_token=rd(self.experts_per_token, 2),
            num_shared_experts=rd(self.num_shared_experts, 1),
            moe_d_ff=rd(self.resolved_moe_d_ff, 256) if self.moe else None,
            kv_lora_rank=rd(self.kv_lora_rank, 64),
            q_lora_rank=rd(self.q_lora_rank, 64),
            qk_nope_dim=rd(self.qk_nope_dim, 32),
            qk_rope_dim=rd(self.qk_rope_dim, 16),
            v_head_dim=rd(self.v_head_dim, 32),
            encoder_layers=rd(self.encoder_layers, 2),
            num_encoder_positions=rd(self.num_encoder_positions, 32),
            num_vision_patches=rd(self.num_vision_patches, 16),
            window=rd(self.window, 64) if self.window else None,
            slstm_offset=1 if self.slstm_period else self.slstm_offset,
            slstm_period=2 if self.slstm_period else 0,
            attn_layer_offset=0,
        )
        if self.ssm_type == "mamba":
            base["attn_layer_period"] = 2
            base["num_layers"] = 2
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa
        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)
