"""The paper's own anomaly-detection CNN (§V-B).

Two 1D-CNN layers (128 / 256 filters), flatten, dense 256 (ReLU), dropout 0.1,
dense softmax over 9 classes, on 78-dim CIC-IDS-2017 feature vectors. This is
the model used for the faithful FedS3A reproduction benchmarks (Tables V-XII).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class CNNConfig:
    name: str = "feds3a-cnn"
    num_features: int = 78
    num_classes: int = 9
    conv_filters: tuple = (128, 256)
    conv_kernel: int = 3
    hidden: int = 256
    dropout: float = 0.1
    source: str = "FedS3A paper §V-B (CIC-IDS 2017)"


CONFIG = CNNConfig()
