"""xlstm-125m [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1] [arXiv:2405.04517].

12 layers, d_model=768, 4 heads, no FFN (d_ff=0; xLSTM blocks carry their own
up/down projections), vocab=50304. One sLSTM block per 8 (offset 7), rest mLSTM.
Sub-quadratic: runs long_500k natively with constant-size recurrent state.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    ssm_type="xlstm",
    slstm_period=8,
    slstm_offset=7,
    tie_embeddings=True,
    source="arXiv:2405.04517",
))
