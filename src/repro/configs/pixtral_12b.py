"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

40 layers, d_model=5120, 32 heads (GQA kv=8), head_dim=128 (attention inner dim
4096 != d_model), d_ff=14336, vocab=131072. Vision encoder + projector are a
stub: `input_specs()` provides (B, num_patches, d_model) patch embeddings that
are prepended to the text sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    num_vision_patches=1024,
    window=8192,              # sliding-window decode carve-in for long_500k
    rope_theta=1e9,
    source="hf:mistralai/Pixtral-12B-2409",
))
