"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60 layers, d_model=5120, 128 heads, expert d_ff=1536, vocab=102400.
Layer 0 is dense (d_ff=12288 in the real model; we keep expert-width shared MLP
semantics via moe_layer_offset=1 ... period 1 with first layer dense).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,               # dense layers' FFN width (layer 0)
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=True,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    moe_layer_period=1,
    moe_layer_offset=0,
    first_k_dense=1,

    window=8192,              # sliding-window decode carve-in for long_500k
    opt_state_dtype="bfloat16",
    source="arXiv:2405.04434",
))
