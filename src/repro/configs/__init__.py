"""Architecture configs. Import `load_all()` to populate the registry."""
import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    InputShape,
    INPUT_SHAPES,
    get_config,
    list_configs,
    register,
)

ARCH_MODULES = [
    "whisper_medium",
    "jamba_1_5_large_398b",
    "deepseek_67b",
    "deepseek_v2_236b",
    "qwen2_1_5b",
    "internlm2_20b",
    "xlstm_125m",
    "llama4_maverick_400b_a17b",
    "granite_8b",
    "pixtral_12b",
    "feds3a_cnn",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
