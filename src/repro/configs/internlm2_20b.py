"""internlm2-20b [dense] — GQA [arXiv:2403.17297].

48 layers, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92544.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    head_dim=128,
    window=8192,              # sliding-window decode carve-in for long_500k
    rope_theta=1e6,
    source="arXiv:2403.17297",
))
