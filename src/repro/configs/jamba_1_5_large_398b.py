"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

72 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536.
One attention layer per 8 (offset 4, as in Jamba blocks); MoE every other layer.
398B total; optimizer states kept in bf16 (memory reality — see DESIGN.md §7).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    ssm_type="mamba",
    attn_layer_period=8,
    attn_layer_offset=4,
    d_state=16,
    conv_kernel=4,
    mamba_expand=2,
    moe=True,
    num_experts=16,
    experts_per_token=2,
    moe_layer_period=2,
    moe_layer_offset=1,
    moe_d_ff=24576,
    opt_state_dtype="bfloat16",
    source="arXiv:2403.19887",
))
