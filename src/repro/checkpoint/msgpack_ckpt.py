"""Minimal msgpack pytree checkpointing (params / optimizer / FL state).

Layout: a single .msgpack file holding {"treedef": <repr>, "leaves": [...]}
where each leaf is {"dtype", "shape", "data"(raw bytes)}. Works for any pytree
of jnp/np arrays + python scalars; keeps the FedS3A server restartable
mid-training (global params, optimizer state, participation matrix, round).
"""
from __future__ import annotations

import os

import jax
import msgpack
import numpy as np


def _pack_leaf(x):
    if isinstance(x, (int, float, bool, str)) or x is None:
        return {"py": x}
    arr = np.asarray(x)
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d):
    if "py" in d:
        return d["py"]
    arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return arr.reshape(d["shape"]).copy()


def save_checkpoint(path, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto()
        if hasattr(treedef, "serialize_using_proto") else None,
        "leaves": [_pack_leaf(jax.device_get(l)) for l in leaves],
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path, like):
    """Restore into the structure of ``like`` (treedef source of truth)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    if len(leaves) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(leaves_like)}")
    out = []
    for got, want in zip(leaves, leaves_like):
        if hasattr(want, "shape") and tuple(np.shape(got)) != tuple(want.shape):
            raise ValueError(f"shape mismatch {np.shape(got)} vs {want.shape}")
        if hasattr(want, "dtype") and hasattr(got, "astype"):
            got = got.astype(want.dtype)
        out.append(got)
    return jax.tree_util.tree_unflatten(treedef, out)
