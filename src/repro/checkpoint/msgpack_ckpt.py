"""Minimal msgpack pytree leaf round-trip.

Layout: a single .msgpack file holding {"leaves": [...]} where each leaf
is {"dtype", "shape", "data"(raw bytes)} or {"py": scalar}. Works for any
pytree of jnp/np arrays + python scalars. The tree STRUCTURE is not
stored: ``load_checkpoint`` restores into the structure of a caller-
provided ``like`` tree and validates leaf count, shapes and dtypes
against it.

This is a building block, not the server restart path — crash-consistent
full-trainer checkpointing (ring, residuals, scheduler heaps, RNG
streams, ledgers) lives in ``core.fleet_ckpt``, which layers manifest
checksums, atomic commit and torn-write fallback on top of plain files
like the ones written here.
"""
from __future__ import annotations

import os

import jax
import msgpack
import numpy as np


def _pack_leaf(x):
    if isinstance(x, (int, float, bool, str)) or x is None:
        return {"py": x}
    arr = np.asarray(x)
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d):
    if "py" in d:
        return d["py"]
    arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return arr.reshape(d["shape"]).copy()


def save_checkpoint(path, tree):
    leaves, _ = jax.tree_util.tree_flatten(tree)
    payload = {
        "leaves": [_pack_leaf(jax.device_get(l)) for l in leaves],
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path, like, *, cast=False):
    """Restore into the structure of ``like`` (treedef source of truth).

    Leaf count and shapes must match ``like`` exactly. Dtypes must match
    too: a checkpoint written as f32 silently reloaded as f16 (or int)
    would corrupt training without a trace, so a mismatch raises unless
    the caller opts in with ``cast=True`` (an explicit, lossy decision).
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    if len(leaves) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(leaves_like)}")
    out = []
    for got, want in zip(leaves, leaves_like):
        if hasattr(want, "shape") and tuple(np.shape(got)) != tuple(want.shape):
            raise ValueError(f"shape mismatch {np.shape(got)} vs {want.shape}")
        if hasattr(want, "dtype") and hasattr(got, "dtype") \
                and got.dtype != np.dtype(want.dtype):
            if not cast:
                raise ValueError(
                    f"dtype mismatch: checkpoint leaf is {got.dtype}, "
                    f"expected {np.dtype(want.dtype)} — pass cast=True to "
                    f"convert explicitly")
            got = got.astype(want.dtype)
        out.append(got)
    return jax.tree_util.tree_unflatten(treedef, out)
