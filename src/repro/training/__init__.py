from repro.training.steps import (  # noqa: F401
    lm_loss,
    make_train_step,
    make_prefill_step,
    make_serve_step,
)
