"""Step functions: training (microbatched grad accumulation), prefill, decode.

These are the functions the launcher jits/lowers for the dry-run, and the
functions FL clients run locally in `repro.core`.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optimizer import adam_update


def lm_loss(cfg: ModelConfig, params, batch, *, window=None, impl="ref",
            moe_impl="einsum", remat=True, seq_parallel=False):
    """Next-token CE (+ MoE aux). VLM: loss only on the text segment."""
    logits, aux, _ = lm.forward(cfg, params, batch, window=window, impl=impl,
                                moe_impl=moe_impl, remat=remat,
                                seq_parallel=seq_parallel)
    tokens = batch["tokens"]
    P = logits.shape[1] - tokens.shape[1]      # prepended patches
    logits = logits[:, P:, :]
    pred = logits[:, :-1]
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred, axis=-1)
    ce = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        ce = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        ce = jnp.mean(ce)
    return ce + cfg.router_aux_loss_coef * aux


def make_train_step(cfg: ModelConfig, *, lr=3e-4, num_microbatches=1,
                    window=None, impl="ref", moe_impl="einsum", l1=0.0,
                    seq_parallel=False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, loss).

    Gradient accumulation over ``num_microbatches`` via lax.scan keeps live
    activation memory at one-microbatch scale (DESIGN.md §7).
    """

    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb, window=window, impl=impl,
                       moe_impl=moe_impl, seq_parallel=seq_parallel)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(t):
                return t.reshape(num_microbatches, t.shape[0] // num_microbatches,
                                 *t.shape[1:])
            mbs = jax.tree.map(split, batch)
            # derive zeros from params so the grad-accumulator scan carry
            # inherits the param sharding (a plain jnp.zeros carry makes
            # GSPMD replicate the whole backward pass)
            zero = jax.tree.map(lambda p: (p * 0).astype(jnp.float32), params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = lax.scan(acc, (zero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss / num_microbatches

        params, opt_state = adam_update(grads, opt_state, params, lr=lr, l1=l1)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len, *, window=None, impl="ref",
                      moe_impl="einsum"):
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch, cache_len, window=window, impl=impl,
                          moe_impl=moe_impl)
    return prefill_step


def make_forward_step(cfg: ModelConfig, *, window=None, impl="ref",
                      moe_impl="einsum", seq_parallel=False):
    """Inference forward (prefill compute; last-token logits only)."""
    def forward_step(params, batch):
        logits, _, _ = lm.forward(cfg, params, batch, window=window, impl=impl,
                                  moe_impl=moe_impl, remat=False,
                                  seq_parallel=seq_parallel, head_mode="last")
        return logits
    return forward_step


def make_serve_step(cfg: ModelConfig, *, ring=False, moe_impl="einsum"):
    """One decode iteration: greedy-sample next token, update cache."""
    def serve_step(params, cache, token, index):
        logits, cache = lm.decode_step(cfg, params, token, cache, index,
                                       ring=ring, moe_impl=moe_impl)
        next_token = jnp.argmax(logits, axis=-1).astype(token.dtype)
        return next_token, logits, cache
    return serve_step
