"""Pallas kernel quantizing + index-packing CSR payloads (``csr_q`` format).

The csr_compact kernel materializes the f32 CSR wire payload — values
(K, cap) f32 + absolute column indices (K, cap) int32, 8 bytes per stored
element. This kernel compresses that payload in place:

* values -> int8 with a per-row absmax scale (``scale = absmax / 127``,
  ``q = clip(round(v / scale), -127, 127)``; an all-zero row gets scale 0),
  or float16 when the caller opts into the wide-dynamic-range fallback;
* absolute columns -> int16 in-block offsets (``col % 512``). csr_compact
  emits columns in ascending order, so the elements of each 512-block are
  contiguous in the payload and a per-row (nblk,) block-count table — the
  same per-block nnz csr_compact's stage 1 already computes — recovers the
  block id of every slot (ref.py::csr_unpack_indices_ref). 512 < 2^15, so
  int16 offsets are exact.

Wire cost per stored element drops from 8 bytes (f32 + int32) to 3 (int8 +
int16), plus 4 bytes/row of scale and 2*ceil(n/512) bytes/row of block
table. Quantization is lossy BY DESIGN: the comm layer computes the
residual against the dequantized decode, so the rounding error joins the
sparsification overflow in the error-feedback store and is re-sent later.

One grid row per client row: the payload width ``cap`` is far smaller than
the dense N the compaction kernel walks, so a whole (1, cap) window per
program keeps the kernel a single fused elementwise pass (absmax reduce +
scale + round + modulo). Oracle: ref.py::csr_quantize2d_ref /
csr_pack_indices_ref.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 512


def _csr_quant_kernel(q_dtype, vals_ref, idx_ref, stored_ref,
                      q_ref, off_ref, scale_ref):
    v = vals_ref[...].astype(jnp.float32)                # (1, cap_pad)
    stored = stored_ref[0, 0]
    slot = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    valid = slot < stored
    v = jnp.where(valid, v, 0.0)
    if q_dtype == "fp16":
        scale_ref[0, 0] = 1.0
        q_ref[...] = v.astype(jnp.float16)
    else:
        absmax = jnp.max(jnp.abs(v))
        scale = absmax / 127.0
        inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0),
                        0.0)
        scale_ref[0, 0] = scale
        q_ref[...] = jnp.clip(jnp.round(v * inv), -127, 127).astype(jnp.int8)
    idx = idx_ref[...]
    off = idx - (idx // BLK) * BLK
    off_ref[...] = jnp.where(valid, off, 0).astype(jnp.int16)


def csr_quantize2d_pallas(values, indices, stored, n, *, q_dtype="int8",
                          interpret=True):
    """values: (K, cap) f32 packed payload values; indices: (K, cap) int32
    absolute columns (ascending per stored prefix); stored: (K,) int32 valid
    prefix lengths; n: the dense row width the indices address.

    Returns (qvals (K, cap) int8|f16, offsets (K, cap) int16,
    block_counts (K, ceil(n/512)) int16, scales (K,) f32). Per-row op —
    shard-invariant under the client mesh.
    """
    assert q_dtype in ("int8", "fp16"), q_dtype
    K, cap = values.shape
    pad = (-cap) % 128                       # lane-align the row window
    cap_pad = cap + pad
    if pad:
        z = jnp.zeros((K, pad), values.dtype)
        values = jnp.concatenate([values, z], axis=1)
        indices = jnp.concatenate(
            [indices, jnp.zeros((K, pad), indices.dtype)], axis=1)
    stored = jnp.asarray(stored, jnp.int32)
    out_dtype = jnp.float16 if q_dtype == "fp16" else jnp.int8
    qvals, offs, scales = pl.pallas_call(
        partial(_csr_quant_kernel, q_dtype),
        grid=(K,),
        in_specs=[pl.BlockSpec((1, cap_pad), lambda k: (k, 0)),
                  pl.BlockSpec((1, cap_pad), lambda k: (k, 0)),
                  pl.BlockSpec((1, 1), lambda k: (k, 0))],
        out_specs=[pl.BlockSpec((1, cap_pad), lambda k: (k, 0)),
                   pl.BlockSpec((1, cap_pad), lambda k: (k, 0)),
                   pl.BlockSpec((1, 1), lambda k: (k, 0))],
        out_shape=[jax.ShapeDtypeStruct((K, cap_pad), out_dtype),
                   jax.ShapeDtypeStruct((K, cap_pad), jnp.int16),
                   jax.ShapeDtypeStruct((K, 1), jnp.float32)],
        interpret=interpret,
    )(values, indices, stored.reshape(K, 1))
    # per-row block-count table: the cheap jnp pass csr_compact's stage 1
    # already demonstrated; reused verbatim from the oracle
    from repro.kernels.ref import csr_pack_indices_ref
    _, counts = csr_pack_indices_ref(indices[:, :cap], stored, n)
    return qvals[:, :cap], offs[:, :cap], counts, scales.reshape(K)
