"""Pallas kernel compacting sparse-delta rows into CSR payloads (§IV-F).

The sparse-delta kernels mask a (K, N) stack of client deltas and count the
survivors, but the masked output is still DENSE — the comm layer merely
*accounted* nnz * 8 bytes while moving (K, N) floats. This kernel materializes
the actual wire payload: per client row, the kept elements are packed into a
``(cap,)`` values buffer and a matching ``(cap,)`` int32 column-index buffer
(ascending column order), so bytes-on-wire is the real size of real arrays
(values + indices + the derived row_ptr), not a promise.

Pipeline (matching the compaction plan the sparse-delta kernel's per-block
nnz output was designed for):

1. per-block keep counts — one cheap jnp pass over the (K, N) stack
   (``keep = (|x| >= thr) & (x != 0)``; exact zeros carry no information and
   never go on the wire, unlike the sparse-delta nnz metric which counts
   every threshold survivor);
2. exclusive scan of the counts along the block axis -> each (row, block)'s
   global write offset;
3. in-kernel scatter on a ``(K, ceil(N/512))`` grid: each block ranks its
   kept elements with an in-block cumsum, packs them with a (512, 512)
   one-hot matmul (the MXU-friendly stream-compaction idiom — Mosaic has no
   vector scatter), and stores the packed (1, 512) window at its dynamic
   global offset via ``pl.store``/``pl.dslice``.

Capacity/overflow contract: ``cap`` is the static per-row payload capacity.
Elements with global rank >= cap fall off the end of the buffer — the
wrapper zero-masks every slot >= ``min(nnz, cap)``, and the comm layer
spills the dropped mass into the error-feedback residual (or drops it,
matching the paper's lossy scheme, when EF is off). The returned ``nnz`` is
the TRUE per-row count, so callers can detect overflow (``nnz > cap``).

Blocks overlap-write by construction: a block stores a full 512-wide window
at offset ``base`` but only its first ``count`` lanes are meaningful; the
next block's window starts at ``base + count`` and overwrites the stale
suffix. Grid iteration over the minor (block) axis is sequential, which is
what makes this sound.

Oracle: kernels/ref.py::csr_compact2d_ref / csr_decode_ref.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 512


def _csr_scatter_kernel(n_valid, cap, x_ref, thr_ref, off_ref,
                        vals_ref, idx_ref):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)               # (1, BLK)
    thr = thr_ref[0, 0]
    base = off_ref[0, 0]                             # global rank of this
                                                     # block's first survivor
    col = j * BLK + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    keep = (jnp.abs(x) >= thr) & (x != 0.0) & (col < n_valid)
    rank = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1          # in-block
    # one-hot pack: out[p] = x[c] where rank[c] == p (exactly one hit per
    # occupied slot, zero elsewhere — exact, no float accumulation)
    slot = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 1)
    oh = (rank[0, :, None] == slot) & keep[0, :, None]             # (c, p)
    vals_c = jnp.sum(oh.astype(jnp.float32) * x[0, :, None], axis=0)
    cols_c = jnp.sum(oh.astype(jnp.int32) * col[0, :, None], axis=0)
    # rank >= cap lands in the pad tail of the (cap + BLK) buffer; a block
    # starting wholly past cap writes at the clamped offset (pad only)
    wb = jnp.minimum(base, cap)
    pl.store(vals_ref, (pl.dslice(0, 1), pl.dslice(wb, BLK)), vals_c[None, :])
    pl.store(idx_ref, (pl.dslice(0, 1), pl.dslice(wb, BLK)), cols_c[None, :])


def csr_compact2d_pallas(x, thresholds, cap, *, interpret=True):
    """x: (K, N) stacked flat deltas, any N; thresholds: (K,); cap: static
    per-row payload capacity (1 <= cap <= N).

    Returns (values (K, cap) f32, indices (K, cap) int32, nnz (K,) int32):
    row k's kept elements (``|x| >= thr_k`` and nonzero) packed in ascending
    column order, zero-padded past ``min(nnz_k, cap)``; ``nnz`` is the true
    (uncapped) count. Per-row op — shard-invariant under a client mesh.
    """
    K, N = x.shape
    cap = int(cap)
    assert 1 <= cap <= N, (cap, N)
    pad = (-N) % BLK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((K, pad), x.dtype)], axis=1)
    nblk = (N + pad) // BLK
    thr = jnp.asarray(thresholds, jnp.float32).reshape(K, 1)
    # stages 1-2: per-block keep counts -> exclusive-scan write offsets
    keep = (jnp.abs(x.astype(jnp.float32)) >= thr) & (x != 0)
    blocks = keep.reshape(K, nblk, BLK).sum(axis=2, dtype=jnp.int32)
    offsets = jnp.cumsum(blocks, axis=1) - blocks
    nnz = jnp.sum(blocks, axis=1)
    cap_pad = cap + BLK                    # overflow windows land in the pad
    vals, idx = pl.pallas_call(
        partial(_csr_scatter_kernel, N, cap),
        grid=(K, nblk),
        in_specs=[pl.BlockSpec((1, BLK), lambda k, j: (k, j)),
                  pl.BlockSpec((1, 1), lambda k, j: (k, 0)),
                  pl.BlockSpec((1, 1), lambda k, j: (k, j))],
        out_specs=[pl.BlockSpec((1, cap_pad), lambda k, j: (k, 0)),
                   pl.BlockSpec((1, cap_pad), lambda k, j: (k, 0))],
        out_shape=[jax.ShapeDtypeStruct((K, cap_pad), jnp.float32),
                   jax.ShapeDtypeStruct((K, cap_pad), jnp.int32)],
        interpret=interpret,
    )(x, thr, offsets)
    stored = jnp.minimum(nnz, cap)
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < stored[:, None]
    vals = jnp.where(valid, vals[:, :cap], 0.0)
    idx = jnp.where(valid, idx[:, :cap], 0)
    return vals, idx, nnz
