"""Pallas TPU flash attention (pl.pallas_call + explicit BlockSpec VMEM tiling).

Grid: (B*H, num_q_blocks, num_kv_blocks), sequential on TPU; the online-softmax
accumulator (acc, m, l) lives in VMEM scratch and persists across the kv-block
grid dimension. Causal/sliding-window masking is derived from program ids, so
no O(S^2) mask tensor ever exists.

Tile sizes default to (128, 128): MXU-aligned (128 lanes), and the working set
q(128,hd) + k(128,hd) + v(128,hd) + acc(128,hd) + tile(128,128) stays well
under the ~16 MB v5e VMEM for hd <= 256.

Oracle: kernels/ref.py::flash_attention_ref (plus models/layers._sdpa).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  qblk, kblk, nk, causal, window, scale):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)               # (qblk, hd)
    k = k_ref[0].astype(jnp.float32)               # (kblk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (qblk,kblk)
    if causal:
        qp = qi * qblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 0)
        kp = ki * kblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 1)
        ok = kp <= qp
        if window is not None:
            ok &= kp > (qp - window)
        s = jnp.where(ok, s, -1e30)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           qblk=128, kblk=128, interpret=True):
    """q,k,v: (B, S, H, hd) with KV already broadcast to all H heads.

    Returns (B, S, H, hd). ``interpret=True`` executes the kernel body in
    Python on CPU (this container); on a real TPU pass interpret=False.
    """
    B, S, H, hd = q.shape
    qblk = min(qblk, S)
    kblk = min(kblk, S)
    assert S % qblk == 0 and S % kblk == 0, (S, qblk, kblk)
    nq, nk = S // qblk, S // kblk
    scale = 1.0 / math.sqrt(hd)

    # (B*H, S, hd) layout: one grid row per (batch, head)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    kernel = functools.partial(_flash_kernel, qblk=qblk, kblk=kblk, nk=nk,
                               causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qblk, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kblk, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, kblk, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, qblk, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pl.ScratchShape((qblk, hd), jnp.float32),
            pl.ScratchShape((qblk,), jnp.float32),
            pl.ScratchShape((qblk,), jnp.float32),
        ] if hasattr(pl, "ScratchShape") else _tpu_scratch(qblk, hd),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def _tpu_scratch(qblk, hd):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((qblk, hd), jnp.float32),
        pltpu.VMEM((qblk,), jnp.float32),
        pltpu.VMEM((qblk,), jnp.float32),
    ]
