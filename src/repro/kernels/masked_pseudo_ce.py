"""Pallas kernel for the paper's pseudo-label loss (Eq. 5).

Fuses softmax + confidence threshold + pseudo-label CE into a single VMEM
pass over the logits: loss_i = -1[max p_i >= theta] * log(max_c p_ic).
The unfused jnp version makes three HBM round-trips over (N, C) logits
(softmax, max, gather); on large unlabeled client batches this layer is the
training hot spot of the FedS3A client step.

Grid: (N // blk,); block (blk, C_pad) in VMEM. C is padded to the 128-lane
width by the wrapper (padded classes get -inf logits).

Oracle: kernels/ref.py::masked_pseudo_ce_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pseudo_ce_kernel(logits_ref, loss_ref, mask_ref, *, threshold):
    x = logits_ref[...].astype(jnp.float32)          # (blk, C_pad)
    m = jnp.max(x, axis=1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=1))
    max_logp = m - lse                               # log max softmax
    mask = (max_logp >= jnp.log(threshold)).astype(jnp.float32)
    loss_ref[...] = -mask * max_logp
    mask_ref[...] = mask


def masked_pseudo_ce_pallas(logits, threshold, *, blk=256, interpret=True):
    """logits: (N, C). Returns (loss (N,), mask (N,))."""
    N, C = logits.shape
    C_pad = max(128, ((C + 127) // 128) * 128)
    blk = min(blk, N)
    if N % blk:
        blk = N  # fall back to one block
    if C_pad != C:
        pad = jnp.full((N, C_pad - C), -1e30, logits.dtype)
        logits = jnp.concatenate([logits, pad], axis=1)

    kernel = functools.partial(_pseudo_ce_kernel, threshold=threshold)
    loss, mask = pl.pallas_call(
        kernel,
        grid=(N // blk,),
        in_specs=[pl.BlockSpec((blk, C_pad), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                   pl.BlockSpec((blk,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.float32),
                   jax.ShapeDtypeStruct((N,), jnp.float32)],
        interpret=interpret,
    )(logits)
    return loss, mask
