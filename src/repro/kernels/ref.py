"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,S,H,hd); k/v: (B,S,H,hd) (KV pre-broadcast to full heads)."""
    B, S, H, hd = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if causal:
        qp = jnp.arange(S)
        mask = qp[None, :] <= qp[:, None]
        if window is not None:
            mask &= qp[None, :] > (qp[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def masked_pseudo_ce_ref(logits, threshold):
    """Paper Eq. 5: confidence-thresholded pseudo-label cross entropy.

    logits: (N, C). Returns (per_sample_loss (N,), mask (N,)).
    loss_i = 1[max softmax_i >= theta] * CE(argmax_i, softmax_i)
           = -mask_i * log(max_i softmax_i)
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    max_logp = jnp.max(logp, axis=-1)
    mask = (jnp.exp(max_logp) >= threshold).astype(jnp.float32)
    return -mask * max_logp, mask


def sparse_delta_ref(x, threshold):
    """Paper §IV-F: magnitude-threshold sparsification of a parameter delta.

    x: (N,) flattened delta, any N. Returns (masked (N,),
    nnz_per_block (ceil(N/512),)) with block size 512 (kernel tiling);
    pad columns never count, even for all-pass thresholds <= 0.
    """
    masked, nnz = sparse_delta2d_ref(x.reshape(1, -1),
                                     jnp.asarray(threshold).reshape(1))
    return masked.reshape(-1), nnz.reshape(-1)


def sparse_delta2d_ref(x, thresholds):
    """Batched §IV-F sparsification: one threshold per stacked client delta.

    x: (K, N) stacked flat deltas, any N; thresholds: (K,). Returns
    (masked (K, N), nnz_per_block (K, ceil(N/512)) int32), block size 512.
    The tail block's pad columns are excluded from the count (matching the
    kernel's in-kernel column guard).
    """
    blk = 512
    K, n = x.shape
    pad = (-n) % blk
    keep = jnp.abs(x) >= thresholds.reshape(K, 1)
    masked = jnp.where(keep, x, 0).astype(x.dtype)
    if pad:
        keep = jnp.concatenate(
            [keep, jnp.zeros((K, pad), keep.dtype)], axis=1)
    nnz = keep.reshape(K, (n + pad) // blk, blk).sum(axis=2).astype(jnp.int32)
    return masked, nnz


def staleness_agg_ref(deltas, weights):
    """Paper Eq. 10 inner sum: staleness/size-weighted client aggregation.

    deltas: (K, N) stacked client deltas; weights: (K,) already containing
    |D_i|/|D_G| * g(r - r_i) * participation mask. Returns (N,) fp32.
    """
    return jnp.einsum("k,kn->n", weights.astype(jnp.float32),
                      deltas.astype(jnp.float32))
