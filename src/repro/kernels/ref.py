"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,S,H,hd); k/v: (B,S,H,hd) (KV pre-broadcast to full heads)."""
    B, S, H, hd = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if causal:
        qp = jnp.arange(S)
        mask = qp[None, :] <= qp[:, None]
        if window is not None:
            mask &= qp[None, :] > (qp[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def masked_pseudo_ce_ref(logits, threshold):
    """Paper Eq. 5: confidence-thresholded pseudo-label cross entropy.

    logits: (N, C). Returns (per_sample_loss (N,), mask (N,)).
    loss_i = 1[max softmax_i >= theta] * CE(argmax_i, softmax_i)
           = -mask_i * log(max_i softmax_i)
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    max_logp = jnp.max(logp, axis=-1)
    mask = (jnp.exp(max_logp) >= threshold).astype(jnp.float32)
    return -mask * max_logp, mask


def sparse_delta_ref(x, threshold):
    """Paper §IV-F: magnitude-threshold sparsification of a parameter delta.

    x: (N,) flattened delta, any N. Returns (masked (N,),
    nnz_per_block (ceil(N/512),)) with block size 512 (kernel tiling);
    pad columns never count, even for all-pass thresholds <= 0.
    """
    masked, nnz = sparse_delta2d_ref(x.reshape(1, -1),
                                     jnp.asarray(threshold).reshape(1))
    return masked.reshape(-1), nnz.reshape(-1)


def sparse_delta2d_ref(x, thresholds):
    """Batched §IV-F sparsification: one threshold per stacked client delta.

    x: (K, N) stacked flat deltas, any N; thresholds: (K,). Returns
    (masked (K, N), nnz_per_block (K, ceil(N/512)) int32), block size 512.
    The tail block's pad columns are excluded from the count (matching the
    kernel's in-kernel column guard).
    """
    blk = 512
    K, n = x.shape
    pad = (-n) % blk
    keep = jnp.abs(x) >= thresholds.reshape(K, 1)
    masked = jnp.where(keep, x, 0).astype(x.dtype)
    if pad:
        keep = jnp.concatenate(
            [keep, jnp.zeros((K, pad), keep.dtype)], axis=1)
    nnz = keep.reshape(K, (n + pad) // blk, blk).sum(axis=2).astype(jnp.int32)
    return masked, nnz


def csr_compact2d_ref(x, thresholds, cap):
    """Compacted CSR wire format for a stack of sparse deltas (§IV-F).

    x: (K, N) stacked flat deltas; thresholds: (K,); cap: static per-row
    payload capacity. Keeps ``(|x| >= thr) & (x != 0)`` — exact zeros pass
    the sparse-delta nnz *metric* at degenerate thresholds but carry no
    information, so they never go on the wire. Returns
    (values (K, cap) f32, indices (K, cap) int32, nnz (K,) int32): kept
    elements packed in ascending column order, zero-padded past
    ``min(nnz, cap)``; ``nnz`` is the true (uncapped) count, so overflow is
    detectable. Rank >= cap overflows off the payload (the comm layer
    spills it into the error-feedback residual).
    """
    K, n = x.shape
    thresholds = jnp.asarray(thresholds, jnp.float32).reshape(K, 1)
    keep = (jnp.abs(x.astype(jnp.float32)) >= thresholds) & (x != 0)
    rank = jnp.cumsum(keep.astype(jnp.int32), axis=1)        # 1-based
    nnz = rank[:, -1]
    # slot s holds the s-th survivor; its column is the first index where
    # the running rank reaches s — a vmapped binary search over the
    # monotone rank vector (an argsort of the drop mask gives the same
    # columns but XLA:CPU sorts measured 7x slower)
    slots = jnp.arange(1, cap + 1, dtype=jnp.int32)
    cols = jax.vmap(lambda r: jnp.searchsorted(r, slots, side="left"))(rank)
    valid = slots[None, :] <= jnp.minimum(nnz, cap)[:, None]
    idx = jnp.where(valid, cols, 0).astype(jnp.int32)
    vals = jnp.where(valid, jnp.take_along_axis(x, idx, axis=1), 0.0)
    return vals.astype(jnp.float32), idx, nnz


def csr_capped_mask_ref(x, thresholds, cap):
    """Dense equivalent of ``csr_decode_ref(*csr_compact2d_ref(...))``:
    survivors whose in-row rank (column order) fits the capacity, everything
    else zeroed. Identical output to the compact -> scatter-decode
    round-trip, but pure elementwise/cumsum ops — no scatter, which XLA:CPU
    executes serially. The engines use this for the dense reconstruction
    (client upload models, distribute targets, residual expansion) while
    the payload arrays themselves feed accounting and the fused
    aggregation; on the distribute path, where only the stored counts are
    consumed, XLA dead-code-eliminates the compaction sort entirely.
    Returns (decoded (K, n), stored per-row counts (K,) int32).
    """
    K, n = x.shape
    thresholds = jnp.asarray(thresholds, jnp.float32).reshape(K, 1)
    keep = (jnp.abs(x.astype(jnp.float32)) >= thresholds) & (x != 0)
    rank = jnp.cumsum(keep.astype(jnp.int32), axis=1)        # 1-based
    decoded = jnp.where(keep & (rank <= cap), x, 0).astype(jnp.float32)
    stored = jnp.minimum(keep.sum(axis=1), cap).astype(jnp.int32)
    return decoded, stored


def csr_decode_ref(values, indices, n):
    """Scatter-add decode of a CSR payload back to dense (K, n) rows.

    Invalid (padding) slots carry value 0 at index 0, so they scatter
    nothing. Round-trip contract: with cap >= nnz,
    ``csr_decode_ref(*csr_compact2d_ref(x, thr, cap)[:2], n)`` equals the
    masked-dense oracle ``sparse_delta2d_ref(x, thr)[0]`` exactly.
    """
    K = values.shape[0]
    rows = jnp.arange(K, dtype=jnp.int32)[:, None]
    return jnp.zeros((K, n), jnp.float32).at[rows, indices].add(
        values.astype(jnp.float32))


def csr_quantize2d_ref(values, stored, *, q_dtype="int8"):
    """Per-row absmax quantization of packed CSR values (``csr_q`` format).

    values: (K, cap) packed f32 payload values; stored: (K,) int32 valid
    prefix lengths. Returns (qvals (K, cap), scales (K,) f32):

    * ``q_dtype="int8"``: ``scale = absmax / 127`` over the stored prefix
      (padding slots are already zero and cannot raise the absmax);
      ``q = clip(round(v / scale), -127, 127)``. An all-zero row gets
      scale 0 and an all-zero payload.
    * ``q_dtype="fp16"`` (fallback for deltas whose dynamic range int8
      cannot hold): values cast to float16, scales all-ones so the
      dequantize path ``q * scale`` is format-agnostic.

    Dequantization is intentionally lossy; the comm layer folds
    ``delta - dequant(decode(payload))`` into the error-feedback residual,
    so the loss is re-sent later rather than forgotten.
    """
    K, cap = values.shape
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < \
        jnp.asarray(stored, jnp.int32)[:, None]
    v = jnp.where(valid, values.astype(jnp.float32), 0.0)
    if q_dtype == "fp16":
        return v.astype(jnp.float16), jnp.ones((K,), jnp.float32)
    absmax = jnp.max(jnp.abs(v), axis=1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(v * inv[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def csr_dequantize_ref(qvals, scales):
    """(K, cap) quantized payload values -> f32. fp16 payloads carry
    all-one scales, so one expression serves both value dtypes."""
    return qvals.astype(jnp.float32) * \
        jnp.asarray(scales, jnp.float32)[:, None]


def quantize_dense_ref(dense, scales, *, q_dtype="int8"):
    """Elementwise quantize->dequantize round-trip of a dense (K, n) row
    stack under the given per-row scales — the scatter-free twin of
    ``csr_decode_ref(csr_dequantize_ref(...))`` when ``dense`` is the
    capped-mask decode and ``scales`` came from the packed payload (the
    absmax over the stored prefix equals the absmax over the dense decode,
    and both paths round the identical quotients)."""
    if q_dtype == "fp16":
        return dense.astype(jnp.float16).astype(jnp.float32)
    s = jnp.asarray(scales, jnp.float32)[:, None]
    inv = jnp.where(s > 0, 1.0 / jnp.where(s > 0, s, 1.0), 0.0)
    q = jnp.clip(jnp.round(dense.astype(jnp.float32) * inv), -127, 127)
    return q * s


def csr_pack_indices_ref(indices, stored, n):
    """Pack (K, cap) absolute int32 CSR columns as per-block int16 offsets.

    Columns are ascending within each stored prefix (csr_compact contract),
    so elements of one 512-block are contiguous and a per-row block-count
    table recovers which block each slot belongs to. Returns
    (offsets (K, cap) int16 = col % 512 with padding zeroed,
    block_counts (K, nblk) int16 with nblk = ceil(n/512)).
    """
    blk = 512
    K, cap = indices.shape
    nblk = max((n + blk - 1) // blk, 1)
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < \
        jnp.asarray(stored, jnp.int32)[:, None]
    offs = jnp.where(valid, indices % blk, 0).astype(jnp.int16)
    blk_id = jnp.where(valid, indices // blk, nblk)   # pad -> out of range
    counts = (blk_id[:, :, None] ==
              jnp.arange(nblk, dtype=jnp.int32)[None, None, :]).sum(axis=1)
    return offs, counts.astype(jnp.int16)


def csr_unpack_indices_ref(offsets, block_counts):
    """Reconstruct absolute int32 columns from the packed ``csr_q`` index
    encoding: slot s lives in the first block whose cumulative count
    exceeds s (vmapped binary search, same idiom as csr_compact2d_ref).
    Padding slots resolve past the last block; they are clamped into range
    (their values are zero, so the scatter-add they feed adds nothing).
    """
    K, cap = offsets.shape
    nblk = block_counts.shape[1]
    cum = jnp.cumsum(block_counts.astype(jnp.int32), axis=1)
    slots = jnp.arange(cap, dtype=jnp.int32)
    blk_id = jax.vmap(
        lambda c: jnp.searchsorted(c, slots, side="right"))(cum)
    blk_id = jnp.minimum(blk_id, nblk - 1)
    return blk_id.astype(jnp.int32) * 512 + offsets.astype(jnp.int32)


def csr_row_ptr_ref(nnz_stored):
    """(K,) stored per-row counts -> the (K+1,) CSR row pointer."""
    nnz_stored = jnp.asarray(nnz_stored, jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(nnz_stored)])


def staleness_agg_ref(deltas, weights):
    """Paper Eq. 10 inner sum: staleness/size-weighted client aggregation.

    deltas: (K, N) stacked client deltas; weights: (K,) already containing
    |D_i|/|D_G| * g(r - r_i) * participation mask. Returns (N,) fp32.
    """
    return jnp.einsum("k,kn->n", weights.astype(jnp.float32),
                      deltas.astype(jnp.float32))
