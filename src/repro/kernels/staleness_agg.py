"""Pallas kernel for the FedS3A aggregation inner sum (Eq. 10).

out = sum_k w_k * delta_k over K stacked client deltas, where w_k already
folds |D_i|/|D_Gk| * g(r - r_i) * participation. Fusing the weighted
reduction means ONE pass over the (K, N) stack instead of K separate
scaled-add passes (the server aggregates every round; for a 1.5B-param model
the stack is 10s of GB).

Grid: (N // 512,); block (K, 512) in VMEM with the weight vector (K, 1).

Oracle: kernels/ref.py::staleness_agg_ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 512


def _staleness_agg_kernel(d_ref, w_ref, o_ref):
    d = d_ref[...].astype(jnp.float32)               # (K, BLK)
    w = w_ref[...].astype(jnp.float32)               # (K, 1)
    o_ref[...] = jnp.sum(d * w, axis=0)


def staleness_agg_pallas(deltas, weights, *, interpret=True):
    """deltas: (K, N) with N % 512 == 0; weights: (K,). Returns (N,) fp32."""
    K, N = deltas.shape
    assert N % BLK == 0, N
    nblk = N // BLK
    out = pl.pallas_call(
        _staleness_agg_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((K, BLK), lambda i: (0, i)),
                  pl.BlockSpec((K, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((BLK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(deltas, weights.reshape(K, 1))
    return out
