"""Pallas kernel for the paper's sparse-difference transmission (§IV-F).

Fuses |x| >= threshold masking with the per-block nonzero count in one VMEM
pass over the flattened parameter delta. The count feeds the ACO metric
(payload bytes / dense bytes) and the comm layer's compaction bookkeeping;
unfused, XLA reads the delta twice (mask, then reduce).

Three entry points share one kernel body:

* ``sparse_delta2d_pallas`` — the batched/sharded-round form: a (K, N) stack
  of K client deltas with a per-client threshold vector, masked and
  nnz-counted in a single call on a 2D grid ``(K, ceil(N / 512))``.
  Thresholds are runtime inputs (a (K, 1) block), so differing per-message
  quantile thresholds do NOT retrigger compilation and never touch the host.
  Under the fleet engine's ``shard_map`` the call sees only the local
  (K/D, N) client shard, so the grid is sized per shard and no cross-device
  traffic is generated — every row is masked against its own threshold.
* ``sparse_delta2d_quantile_pallas`` — fused per-shard top-|.| form: the
  strided-sample magnitude quantile per LOCAL row feeds the kernel as its
  threshold vector. Thresholds are a pure per-row statistic, so the result
  is invariant to how rows are sharded across devices.
* ``sparse_delta_pallas`` — the original single-delta form, the K=1 case.

Grid: (K, ceil(N / 512)); blocks (1, 512) — 512 = 4 * 128 lanes — with the
threshold in a (1, 1) block per grid row. N that is not a multiple of 512 is
zero-padded here, and the kernel masks the pad columns out of the nnz count
(an in-kernel column-index guard), so degenerate all-pass thresholds
(thr <= 0) do not overcount the pad.

Oracle: kernels/ref.py::sparse_delta_ref / sparse_delta2d_ref.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 512
QUANTILE_SAMPLE = 2048


def _sparse_delta_kernel(n_valid, x_ref, thr_ref, out_ref, nnz_ref):
    j = pl.program_id(1)
    x = x_ref[...]                                   # (1, BLK)
    thr = thr_ref[0, 0]
    col = j * BLK + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    keep = (jnp.abs(x.astype(jnp.float32)) >= thr) & (col < n_valid)
    out_ref[...] = jnp.where(keep, x, 0).astype(out_ref.dtype)
    nnz_ref[...] = jnp.sum(keep.astype(jnp.int32), axis=1, keepdims=True)


def sparse_delta2d_pallas(x, thresholds, *, interpret=True):
    """x: (K, N), any N; thresholds: (K,) runtime scalars.

    Returns (masked (K, N), nnz (K, ceil(N/512)) int32) — every client's
    delta is masked against its own threshold in one kernel launch. Pad
    columns (to the 512 block) are excluded from the count in-kernel.
    """
    K, N = x.shape
    pad = (-N) % BLK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((K, pad), x.dtype)], axis=1)
    nblk = (N + pad) // BLK
    thresholds = jnp.asarray(thresholds, jnp.float32).reshape(K, 1)
    masked, nnz = pl.pallas_call(
        partial(_sparse_delta_kernel, N),
        grid=(K, nblk),
        in_specs=[pl.BlockSpec((1, BLK), lambda k, j: (k, j)),
                  pl.BlockSpec((1, 1), lambda k, j: (k, 0))],
        out_specs=[pl.BlockSpec((1, BLK), lambda k, j: (k, j)),
                   pl.BlockSpec((1, 1), lambda k, j: (k, j))],
        out_shape=[jax.ShapeDtypeStruct((K, N + pad), x.dtype),
                   jax.ShapeDtypeStruct((K, nblk), jnp.int32)],
        interpret=interpret,
    )(x, thresholds)
    return masked[:, :N], nnz


def local_quantile_thresholds(x, keep_frac, *, sample=QUANTILE_SAMPLE):
    """(K,) per-row |.|-quantile thresholds from a strided ``sample``-point
    subsample (matches sparse_comm's sampled-quantile semantics: an exact
    sort over millions of params per message dominates wall time; a 2k
    sample keeps the kept-fraction standard error under ~1%).

    Per-row statistic only — under ``shard_map`` each shard computes the
    thresholds of its local rows and the result matches the unsharded run.
    """
    K, N = x.shape
    stride = max(N // sample, 1)
    return jnp.quantile(jnp.abs(x[:, ::stride].astype(jnp.float32)),
                        1.0 - keep_frac, axis=1)


def sparse_delta2d_quantile_pallas(x, keep_frac, *, interpret=True):
    """Fused top-``keep_frac``-by-magnitude sparsification of a client shard.

    x: (K, N) local client deltas. Computes the per-row sampled-quantile
    threshold and feeds it straight into the 2D-grid kernel — one fused
    dispatch per shard, thresholds never leave the device. Returns
    (masked (K, N), nnz (K, ceil(N/512)), thresholds (K,)).
    """
    thr = local_quantile_thresholds(x, keep_frac)
    masked, nnz = sparse_delta2d_pallas(x, thr, interpret=interpret)
    return masked, nnz, thr


def sparse_delta_pallas(x, threshold, *, interpret=True):
    """x: (N,), any N. Returns (masked (N,), nnz (ceil(N/512),) int32).

    ``threshold`` may be a python float or a device scalar — it is a runtime
    input either way (no recompile per distinct threshold).
    """
    N = x.shape[0]
    thr = jnp.asarray(threshold, jnp.float32).reshape(1)
    masked, nnz = sparse_delta2d_pallas(x.reshape(1, N), thr,
                                        interpret=interpret)
    return masked.reshape(N), nnz.reshape(-1)
