"""Pallas kernel for the paper's sparse-difference transmission (§IV-F).

Fuses |x| >= threshold masking with the per-block nonzero count in one VMEM
pass over the flattened parameter delta. The count feeds the ACO metric
(payload bytes / dense bytes) and the comm layer's compaction bookkeeping;
unfused, XLA reads the delta twice (mask, then reduce).

Grid: (N // 512,); block (1, 512) — 512 = 4 * 128 lanes.

Oracle: kernels/ref.py::sparse_delta_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 512


def _sparse_delta_kernel(x_ref, out_ref, nnz_ref, *, threshold):
    x = x_ref[...]                                   # (1, BLK)
    keep = jnp.abs(x.astype(jnp.float32)) >= threshold
    out_ref[...] = jnp.where(keep, x, 0).astype(out_ref.dtype)
    nnz_ref[...] = jnp.sum(keep.astype(jnp.int32), axis=1)


def sparse_delta_pallas(x, threshold, *, interpret=True):
    """x: (N,) with N % 512 == 0. Returns (masked (N,), nnz (N//512,) int32)."""
    N = x.shape[0]
    assert N % BLK == 0, N
    nblk = N // BLK
    kernel = functools.partial(_sparse_delta_kernel, threshold=threshold)
    masked, nnz = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, BLK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, BLK), lambda i: (i, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblk, BLK), x.dtype),
                   jax.ShapeDtypeStruct((nblk,), jnp.int32)],
        interpret=interpret,
    )(x.reshape(nblk, BLK))
    return masked.reshape(N), nnz
