"""Pallas kernel for the paper's sparse-difference transmission (§IV-F).

Fuses |x| >= threshold masking with the per-block nonzero count in one VMEM
pass over the flattened parameter delta. The count feeds the ACO metric
(payload bytes / dense bytes) and the comm layer's compaction bookkeeping;
unfused, XLA reads the delta twice (mask, then reduce).

Two entry points share one kernel body:

* ``sparse_delta2d_pallas`` — the batched-round form: a (K, N) stack of K
  client deltas with a per-client threshold vector, masked and nnz-counted in
  a single call on a 2D grid ``(K, N // 512)``. Thresholds are runtime
  inputs (a (K, 1) block), so differing per-message quantile thresholds do
  NOT retrigger compilation and never touch the host.
* ``sparse_delta_pallas`` — the original single-delta form, now the K=1
  special case.

Grid: (K, N // 512); blocks (1, 512) — 512 = 4 * 128 lanes — with the
threshold in a (1, 1) block per grid row.

Oracle: kernels/ref.py::sparse_delta_ref / sparse_delta2d_ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 512


def _sparse_delta_kernel(x_ref, thr_ref, out_ref, nnz_ref):
    x = x_ref[...]                                   # (1, BLK)
    thr = thr_ref[0, 0]
    keep = jnp.abs(x.astype(jnp.float32)) >= thr
    out_ref[...] = jnp.where(keep, x, 0).astype(out_ref.dtype)
    nnz_ref[...] = jnp.sum(keep.astype(jnp.int32), axis=1, keepdims=True)


def sparse_delta2d_pallas(x, thresholds, *, interpret=True):
    """x: (K, N) with N % 512 == 0; thresholds: (K,) runtime scalars.

    Returns (masked (K, N), nnz (K, N//512) int32) — every client's delta is
    masked against its own threshold in one kernel launch.
    """
    K, N = x.shape
    assert N % BLK == 0, N
    nblk = N // BLK
    thresholds = jnp.asarray(thresholds, jnp.float32).reshape(K, 1)
    masked, nnz = pl.pallas_call(
        _sparse_delta_kernel,
        grid=(K, nblk),
        in_specs=[pl.BlockSpec((1, BLK), lambda k, j: (k, j)),
                  pl.BlockSpec((1, 1), lambda k, j: (k, 0))],
        out_specs=[pl.BlockSpec((1, BLK), lambda k, j: (k, j)),
                   pl.BlockSpec((1, 1), lambda k, j: (k, j))],
        out_shape=[jax.ShapeDtypeStruct((K, N), x.dtype),
                   jax.ShapeDtypeStruct((K, nblk), jnp.int32)],
        interpret=interpret,
    )(x, thresholds)
    return masked, nnz


def sparse_delta_pallas(x, threshold, *, interpret=True):
    """x: (N,) with N % 512 == 0. Returns (masked (N,), nnz (N//512,) int32).

    ``threshold`` may be a python float or a device scalar — it is a runtime
    input either way (no recompile per distinct threshold).
    """
    N = x.shape[0]
    assert N % BLK == 0, N
    thr = jnp.asarray(threshold, jnp.float32).reshape(1)
    masked, nnz = sparse_delta2d_pallas(x.reshape(1, N), thr,
                                        interpret=interpret)
    return masked.reshape(N), nnz.reshape(-1)
