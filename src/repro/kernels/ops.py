"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels run in interpret mode; on TPU they lower
to Mosaic. ``masked_pseudo_ce`` carries a custom VJP so the FedS3A client loss
is differentiable (backward is the standard (p - onehot) * mask softmax grad).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.csr_compact import csr_compact2d_pallas
from repro.kernels.csr_quant import csr_quantize2d_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.masked_pseudo_ce import masked_pseudo_ce_pallas
from repro.kernels.ref import csr_decode_ref
from repro.kernels.sparse_delta import (sparse_delta2d_pallas,
                                        sparse_delta2d_quantile_pallas,
                                        sparse_delta_pallas)
from repro.kernels.staleness_agg import staleness_agg_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, window=None, causal=True):
    """q: (B,S,Hq,hd); k/v: (B,S,Hkv,hd) — GQA KV broadcast handled here."""
    G = q.shape[2] // k.shape[2]
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def masked_pseudo_ce(logits, threshold):
    loss, mask = masked_pseudo_ce_pallas(logits, threshold,
                                         interpret=_interpret())
    return loss, mask


def _mpce_fwd(logits, threshold):
    loss, mask = masked_pseudo_ce(logits, threshold)
    return (loss, mask), (logits, mask)


def _mpce_bwd(threshold, res, g):
    logits, mask = res
    g_loss = g[0]
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1],
                            dtype=jnp.float32)
    d = (p - onehot) * (mask * g_loss)[:, None]
    return (d.astype(logits.dtype),)


masked_pseudo_ce.defvjp(_mpce_fwd, _mpce_bwd)


def sparse_delta(x, threshold):
    """Flattened delta -> (masked delta, per-512-block nnz). Tail padding
    (and its exclusion from the count) is handled inside the kernel wrapper."""
    return sparse_delta_pallas(x, threshold, interpret=_interpret())


def sparse_delta_batch(x, thresholds):
    """(K, N) stacked flat deltas x (K,) thresholds -> (masked (K, N),
    per-512-block nnz (K, nblk)) in ONE kernel launch over a 2D grid.

    Shard-safe: under the fleet engine's ``shard_map`` the (K, N) stack is
    the local client shard and the grid covers exactly its rows."""
    return sparse_delta2d_pallas(x, thresholds, interpret=_interpret())


def sparse_delta_topfrac(x, keep_frac):
    """Fused per-shard top-|.| sparsification: per-row sampled-quantile
    thresholds + 2D-grid mask/count, one dispatch. Returns
    (masked (K, N), nnz (K, nblk), thresholds (K,))."""
    return sparse_delta2d_quantile_pallas(x, keep_frac,
                                          interpret=_interpret())


def csr_compact(x, thresholds, cap):
    """(K, N) stacked flat deltas x (K,) thresholds -> the compacted CSR
    wire payload (values (K, cap) f32, indices (K, cap) int32, true nnz
    (K,) int32) in one grid launch (per-block counts -> exclusive scan ->
    in-kernel scatter). Per-row op, so shard-safe under the client mesh."""
    return csr_compact2d_pallas(x, thresholds, cap, interpret=_interpret())


def csr_quantize(values, indices, stored, n, *, q_dtype="int8"):
    """Quantize + index-pack a compacted CSR payload (``csr_q`` format):
    (values (K, cap) f32, indices (K, cap) int32, stored (K,) int32) ->
    (qvals (K, cap) int8|f16, offsets (K, cap) int16,
    block_counts (K, ceil(n/512)) int16, scales (K,) f32). Per-row op,
    shard-safe under the client mesh."""
    return csr_quantize2d_pallas(values, indices, stored, n,
                                 q_dtype=q_dtype, interpret=_interpret())


def csr_decode(values, indices, n):
    """Scatter-add decode of a CSR payload to dense (K, n) f32 rows.
    Padding slots hold value 0 at index 0 and scatter nothing."""
    return csr_decode_ref(values, indices, n)


def staleness_agg(deltas, weights):
    """(K, N) stacked deltas x (K,) weights -> (N,) fp32 weighted sum."""
    k, n = deltas.shape
    pad = (-n) % 512
    if pad:
        deltas = jnp.concatenate(
            [deltas, jnp.zeros((k, pad), deltas.dtype)], axis=1)
    return staleness_agg_pallas(deltas, weights, interpret=_interpret())[:n]
