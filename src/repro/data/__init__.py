from repro.data.synthetic_cicids import (  # noqa: F401
    CLASS_NAMES,
    BASIC_SCENARIO,
    BALANCED_SCENARIO,
    make_dataset,
    make_fleet_dataset,
    shannon_entropy,
)
from repro.data.synthetic_lm import make_lm_dataset  # noqa: F401
