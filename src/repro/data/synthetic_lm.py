"""Synthetic token-sequence federation for the LM-as-classifier path.

The chunked-parameter-axis engines federate real language models from the
config zoo as final-token classifiers (``core.model_adapter.LMAdapter``):
clients hold unlabeled token sequences, the server holds a labeled split,
and the label is a class id drawn from the vocabulary. This module builds
such a federation with the same dict contract as
``data.synthetic_cicids.make_dataset`` — ``clients`` (list of ``{"x", "y"}``
with the hidden ``"y"`` for evaluation only), ``server`` / ``test`` labeled
splits, per-client ``counts`` and Shannon ``entropy``, and optional ``pool``
aliasing for fleet-scale runs.

Token rows are float32 ``(n_i, seq_len)`` arrays (exact for any vocab below
2**24) so they ride the trainer's existing padded-data plumbing unchanged;
the adapter casts to int32 at the loss.

The task is a bag-of-signature-words problem: each class owns a small set
of signature tokens that dominate its sequences, so a reduced transformer's
final-position logits separate the classes within a few federated rounds —
learnable, but not trivially linearly separable at the embedding layer.
Class counts tile a non-IID concentration pattern (client i majors in class
``i % C``), echoing the paper's Table III heterogeneity.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic_cicids import shannon_entropy

SIGNATURE_TOKENS = 8       # tokens owned by each class
SIGNATURE_FRAC = 0.7       # fraction of each sequence drawn from them


class _TokenClassModel:
    """Per-class token distributions over a shared vocabulary."""

    def __init__(self, rng, vocab_size, num_classes):
        if vocab_size < num_classes * (SIGNATURE_TOKENS + 1):
            raise ValueError(
                f"vocab_size={vocab_size} too small for {num_classes} "
                f"classes with {SIGNATURE_TOKENS} signature tokens each")
        self.vocab_size = vocab_size
        self.num_classes = num_classes
        # class ids double as label tokens; signature tokens live past them
        perm = num_classes + rng.permutation(vocab_size - num_classes)
        self.signatures = perm[:num_classes * SIGNATURE_TOKENS].reshape(
            num_classes, SIGNATURE_TOKENS)

    def sample(self, rng, cls, n, seq_len):
        sig = rng.choice(self.signatures[cls], size=(n, seq_len))
        noise = rng.integers(self.num_classes, self.vocab_size,
                             (n, seq_len))
        use_sig = rng.random((n, seq_len)) < SIGNATURE_FRAC
        return np.where(use_sig, sig, noise).astype(np.float32)


def make_lm_dataset(num_clients=8, *, vocab_size=512, seq_len=16,
                    num_classes=8, samples_per_client=48, jitter=0.3,
                    server_frac=0.25, test_samples=128, seed=0, pool=None):
    """Build the token-sequence federation (see module docstring).

    ``pool``: materialize only ``pool`` distinct client shards and alias
    them cyclically (array references, no copies) — same contract as
    ``make_fleet_dataset``.
    """
    rng = np.random.default_rng(seed)
    model = _TokenClassModel(rng, vocab_size, num_classes)

    P = num_clients if pool is None else max(1, min(int(pool), num_clients))
    # non-IID concentration: client i majors (~60%) in class i % C, the
    # rest spreads over two neighbour classes
    counts = np.zeros((P, num_classes), int)
    for i in range(P):
        n_i = max(int(samples_per_client
                      * rng.uniform(1.0 - jitter, 1.0 + jitter)), 4)
        major = i % num_classes
        counts[i, major] = int(n_i * 0.6)
        counts[i, (major + 1) % num_classes] = int(n_i * 0.25)
        counts[i, (major + 2) % num_classes] = \
            n_i - counts[i, major] - counts[i, (major + 1) % num_classes]

    def build_split(split_counts):
        xs, ys = [], []
        for c in range(num_classes):
            n = int(split_counts[c])
            if n == 0:
                continue
            xs.append(model.sample(rng, c, n, seq_len))
            ys.append(np.full(n, c, np.int32))
        x = np.concatenate(xs) if xs else \
            np.zeros((0, seq_len), np.float32)
        y = np.concatenate(ys) if ys else np.zeros((0,), np.int32)
        perm = rng.permutation(len(x))
        return {"x": x[perm], "y": y[perm]}

    clients = [build_split(counts[i]) for i in range(P)]
    total = int(counts.sum())
    even = np.full(num_classes,
                   max(int(total * server_frac) // num_classes, 2))
    server = build_split(even)
    test = build_split(np.full(num_classes,
                               max(test_samples // num_classes, 4)))
    entropy = np.array([shannon_entropy(c) for c in counts])

    data = {"clients": clients, "server": server, "test": test,
            "counts": counts, "entropy": entropy}
    if pool is not None:
        reps = -(-num_clients // P)
        data["clients"] = (data["clients"] * reps)[:num_clients]
        data["counts"] = np.tile(counts, (reps, 1))[:num_clients]
        data["entropy"] = np.tile(entropy, reps)[:num_clients]
        data["pool"] = P
    return data
