"""Synthetic CIC-IDS-2017-like dataset (DESIGN.md §8: the real dataset is not
available offline; repro band 2/5 anticipated this data gate).

78 continuous features, 9 classes (Benign + 8 attacks), class-conditional
two-component Gaussian mixtures with enough separation that >98% accuracy is
achievable — matching the paper's operating regime (its CNN reaches 98%+).

Per-client sample counts reproduce Table III exactly (scaled by ``scale``),
for both the basic (non-IID) and balanced (IID) scenarios; Shannon entropies
therefore match the table too. The server holds a stratified labeled split
(~5% of training data by default, §V-D5).
"""
from __future__ import annotations

import numpy as np

CLASS_NAMES = [
    "Benign", "DoS Hulk", "PortScan", "DDoS", "DoS GoldenEye",
    "FTP-Patator", "SSH-Patator", "DoS slowloris", "DoS Slowhttp",
]
NUM_CLASSES = len(CLASS_NAMES)
NUM_FEATURES = 78

# Table III — exact per-client class counts.
BASIC_SCENARIO = np.array([
    [4184, 37744, 19774, 12784, 1224, 884, 562, 524, 677],
    [64408, 16, 0, 0, 0, 1189, 1674, 1551, 1632],
    [10592, 19480, 34056, 1044, 992, 0, 0, 0, 0],
    [52248, 5883, 0, 0, 0, 0, 0, 0, 0],
    [256, 22000, 16072, 5456, 1016, 0, 0, 0, 0],
    [960, 18728, 8517, 10724, 264, 0, 0, 0, 0],
    [549, 19696, 9368, 0, 588, 0, 0, 478, 532],
    [24740, 0, 0, 0, 0, 0, 0, 0, 0],
    [1008, 8764, 0, 8764, 1788, 1855, 855, 0, 0],
    [776, 8064, 8064, 0, 0, 0, 0, 0, 0],
])

BALANCED_SCENARIO = np.array([
    [26848, 23744, 16465, 7308, 1322, 800, 665, 579, 625],
    [24146, 21354, 14808, 6573, 1189, 719, 598, 521, 562],
    [22670, 20049, 13903, 6171, 1116, 675, 562, 489, 528],
    [19918, 17615, 12215, 5422, 981, 593, 494, 430, 464],
    [15350, 13576, 9414, 4179, 756, 457, 380, 331, 357],
    [13429, 11877, 8236, 3656, 661, 400, 333, 290, 313],
    [10694, 9458, 6558, 2911, 527, 318, 265, 231, 249],
    [8477, 7497, 5199, 2308, 417, 252, 210, 183, 197],
    [7892, 6980, 4840, 2148, 389, 235, 196, 170, 184],
    [5792, 5122, 3552, 1577, 285, 172, 144, 125, 135],
])


def shannon_entropy(counts) -> float:
    """Paper Eq. 13: normalized Shannon entropy of a client's class counts.

    The paper normalizes by log K with K=10 (Table III's entropy column only
    reproduces with 10, not the 9 classes of the final dataset — presumably
    benign + 9 pre-filtering attack types).
    """
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return 0.0
    p = counts[counts > 0] / total
    if (counts > 0).sum() <= 1:
        return 0.0
    return float(-(p * np.log(p)).sum() / np.log(10))


class _ClassModel:
    """Two-component Gaussian mixture per class in feature space."""

    def __init__(self, rng: np.random.Generator, separation=4.0):
        # class means: unit directions scaled to ``separation`` sigma apart
        self.means = rng.normal(0, 1, (NUM_CLASSES, 2, NUM_FEATURES))
        self.means /= np.linalg.norm(self.means, axis=-1, keepdims=True)
        self.means *= separation
        # the two mixture components of one class sit near each other
        self.means[:, 1] = self.means[:, 0] + rng.normal(
            0, 0.15, (NUM_CLASSES, NUM_FEATURES))
        self.scales = rng.uniform(0.6, 1.4, (NUM_CLASSES, NUM_FEATURES))

    def sample(self, rng: np.random.Generator, cls: int, n: int):
        comp = rng.integers(0, 2, n)
        x = rng.normal(0, 1, (n, NUM_FEATURES)) * self.scales[cls]
        return (x + self.means[cls, comp]).astype(np.float32)


def make_dataset(scenario="basic", *, scale=0.02, server_frac=0.05,
                 test_frac=0.1, seed=0, separation=8.0):
    """Build the federated dataset.

    Returns dict with:
      clients: list of {"x": (n_i, 78)} unlabeled client data
               (+ hidden "y" for evaluation/oracle use only)
      server:  {"x", "y"} labeled server data (stratified, server_frac of train)
      test:    {"x", "y"}
      counts:  (M, 9) per-client class counts (scaled)
      entropy: (M,) per-client Shannon entropies
    """
    table = BASIC_SCENARIO if scenario == "basic" else BALANCED_SCENARIO
    rng = np.random.default_rng(seed)
    model = _ClassModel(rng, separation=separation)

    counts = np.maximum((table * scale).astype(int), 0)
    return _build_federation(counts, model, rng, server_frac, test_frac)


def make_fleet_dataset(num_clients, *, scenario="basic", scale=0.001,
                       jitter=0.3, server_frac=0.05, test_frac=0.1, seed=0,
                       separation=8.0, pool=None):
    """Fleet-scale federation: ``num_clients`` clients whose class counts
    tile the Table III rows cyclically, each scaled by ``scale`` and a
    per-client uniform size jitter of ±``jitter`` — a heterogeneous IoT
    fleet of arbitrary size with the paper's non-IID (or balanced) label
    structure. Same return shape as ``make_dataset``. Keep ``scale`` small:
    the fleet engine pads every client to the fleet-wide max batch count.

    ``pool``: materialize only ``pool`` distinct client shards and alias
    them cyclically across the fleet (clients share array REFERENCES, no
    copies) — million-client scale runs in the memory of a ``pool``-client
    dataset. The returned dict carries ``"pool"`` so the trainer's paged
    data path stores just the distinct rows. Server/test splits are built
    from the pool's counts (they only set labeled-split sizes).
    """
    table = BASIC_SCENARIO if scenario == "basic" else BALANCED_SCENARIO
    rng = np.random.default_rng(seed)
    model = _ClassModel(rng, separation=separation)

    P = num_clients if pool is None else max(1, min(int(pool), num_clients))
    rows = table[np.arange(P) % len(table)]
    factors = rng.uniform(1.0 - jitter, 1.0 + jitter, (P, 1))
    counts = np.maximum((rows * scale * factors).astype(int), 0)
    # every client holds at least one sample of its majority class so no
    # round sees an empty shard
    empty = counts.sum(axis=1) == 0
    counts[empty, np.argmax(rows[empty], axis=1)] = 1
    data = _build_federation(counts, model, rng, server_frac, test_frac)
    if pool is not None:
        reps = -(-num_clients // P)
        data["clients"] = (data["clients"] * reps)[:num_clients]
        data["counts"] = np.tile(counts, (reps, 1))[:num_clients]
        data["entropy"] = np.tile(data["entropy"], reps)[:num_clients]
        data["pool"] = P
    return data


def _build_federation(counts, model, rng, server_frac, test_frac):
    clients = []
    for i in range(counts.shape[0]):
        xs, ys = [], []
        for c in range(NUM_CLASSES):
            n = int(counts[i, c])
            if n == 0:
                continue
            xs.append(model.sample(rng, c, n))
            ys.append(np.full(n, c, np.int32))
        x = np.concatenate(xs) if xs else np.zeros((0, NUM_FEATURES), np.float32)
        y = np.concatenate(ys) if ys else np.zeros((0,), np.int32)
        perm = rng.permutation(len(x))
        clients.append({"x": x[perm], "y": y[perm]})

    total_train = int(counts.sum())
    overall = counts.sum(axis=0)

    def stratified(n_total):
        frac = overall / max(overall.sum(), 1)
        xs, ys = [], []
        for c in range(NUM_CLASSES):
            n = max(int(round(n_total * frac[c])), 2)
            xs.append(model.sample(rng, c, n))
            ys.append(np.full(n, c, np.int32))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        perm = rng.permutation(len(x))
        return {"x": x[perm], "y": y[perm]}

    server = stratified(max(int(total_train * server_frac), NUM_CLASSES * 2))
    test = stratified(max(int(total_train * test_frac), NUM_CLASSES * 10))
    entropy = np.array([shannon_entropy(c) for c in counts])
    return {
        "clients": clients,
        "server": server,
        "test": test,
        "counts": counts,
        "entropy": entropy,
    }
