"""Adam / SGD with optional decoupled weight decay and L1 regularisation.

The FedS3A paper (§IV-F) adds L1 regularisation to the model parameters so the
inter-round parameter difference is sparse — implemented here as an L1
subgradient term, shared by the small CNN runs and the big-model trainer.

Optimizer state dtype is configurable (``bfloat16`` for the >=200B models, see
DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params, dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                weight_decay=0.0, l1=0.0):
    t = opt_state["t"] + 1
    tf = t.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        if l1:
            g = g + l1 * jnp.sign(p.astype(jnp.float32))
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        mhat = m_new / (1 - b1 ** tf)
        vhat = v_new / (1 - b2 ** tf)
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    params_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, {"m": m_new, "v": v_new, "t": t}


def sgd_update(grads, params, *, lr, l1=0.0):
    def upd(g, p):
        g = g.astype(jnp.float32)
        if l1:
            g = g + l1 * jnp.sign(p.astype(jnp.float32))
        return (p.astype(jnp.float32) - lr * g).astype(p.dtype)
    return jax.tree.map(upd, grads, params)
