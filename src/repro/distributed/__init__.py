from repro.distributed.sharding import (  # noqa: F401
    param_specs,
    batch_specs,
    cache_specs,
    maybe_constraint,
)
