"""Sharding rules: map parameter/activation pytrees to PartitionSpecs.

Mesh axes:
  pod    — slow inter-pod links (multi-pod mesh only); batch-parallel
  data   — batch parallel; with ``fsdp`` also shards param storage (ZeRO-3-ish)
  model  — tensor/expert parallel (attention heads, FFN width, experts)

Rules are name-based over the parameter tree produced by ``lm.init_params``.
Leaves under ``params["scan"]`` carry a leading stacked layer dim that is never
sharded. pjit *argument* shardings must divide dimensions exactly (unlike
internal constraints, which pad), so every rule is filtered through ``_fit``:
axes that do not divide the dim are dropped (tuple axes keep the longest
dividing prefix) — e.g. whisper's vocab 51865 stays unsharded, GQA kv=8 heads
fall back to sequence sharding on a 16-way model axis.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig


def _active_mesh():
    """Version-compat: the mesh currently in scope, or None.

    ``jax.sharding.get_abstract_mesh`` only exists on newer JAX; older
    releases (e.g. 0.4.x) track the ``with Mesh(...):`` context through
    ``thread_resources.env.physical_mesh`` instead.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        return None if mesh.empty else mesh
    try:
        from jax._src.mesh import thread_resources
    except ImportError:  # very old layout
        from jax.interpreters.pxla import thread_resources
    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on newer JAX,
    the plain ``with mesh:`` context (which pjit consults) on older JAX."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def jit_shardings(mesh, tree):
    """PartitionSpec pytree -> whatever ``jax.jit(in_shardings=...)`` takes.

    Newer JAX accepts bare PartitionSpecs under an active (set_mesh) mesh;
    older releases require concrete ``NamedSharding``s, so bind the mesh
    explicitly there (None leaves become fully-replicated specs).
    """
    if getattr(jax, "set_mesh", None) is not None:
        return tree
    from jax.sharding import NamedSharding

    def conv(s):
        if s is None:
            return NamedSharding(mesh, P())
        if isinstance(s, P):
            return NamedSharding(mesh, s)
        return s

    return jax.tree.map(conv, tree,
                        is_leaf=lambda s: isinstance(s, P) or s is None)


def maybe_constraint(x, spec_dims):
    """with_sharding_constraint iff a mesh with the named axes is active.

    Entries may be axis names, tuples of axis names (filtered to the axes
    present on the active mesh), or None.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def fix(d):
        if isinstance(d, str):
            return d if d in names else None
        if isinstance(d, (tuple, list)):
            kept = tuple(a for a in d if a in names)
            return kept if kept else None
        return None

    dims = tuple(fix(d) for d in spec_dims)
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(x, P(*dims))


def batch_axes(mesh_axis_names):
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names)


def mesh_axis_sizes(mesh) -> dict:
    return {name: int(size) for name, size in
            zip(mesh.axis_names, mesh.devices.shape)}


def _fit(spec_dims, shape, axis_sizes):
    """Drop axes that do not divide their dim (pjit argument requirement)."""
    out = []
    for i, d in enumerate(spec_dims):
        if d is None or i >= len(shape):
            out.append(None)
            continue
        axes = (d,) if isinstance(d, str) else tuple(d)
        axes = tuple(a for a in axes if a in axis_sizes)
        # longest prefix whose size product divides the dim
        kept = []
        prod = 1
        for a in axes:
            if shape[i] % (prod * axis_sizes[a]) == 0:
                kept.append(a)
                prod *= axis_sizes[a]
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


# ---------------------------------------------------------------------------
# client-axis mesh (the FedS3A fleet engine)
# ---------------------------------------------------------------------------
CLIENT_AXIS = "clients"

# (K, N) flat client stacks: rows over devices, params replicated per row
CLIENT_STACK_SPEC = P(CLIENT_AXIS, None)
# (K,) per-client scalars (weights, thresholds, nnz)
CLIENT_VEC_SPEC = P(CLIENT_AXIS)
# replicated values (the global model, the supervised weight)
REPLICATED_SPEC = P()
# one CSR payload triple — (K, cap) values, (K, cap) column indices, (K,)
# stored counts — sharded row-wise like the stacks they compact: each device
# packs/decodes only its local client rows, so compaction adds no collective
CLIENT_PAYLOAD_SPECS = (CLIENT_STACK_SPEC, CLIENT_STACK_SPEC,
                        CLIENT_VEC_SPEC)
# the paged client store (``client_store="paged"``) removes the (M, rcap)
# device-resident residual source entirely: the round stages consume a
# gathered (Kp, rcap) PARTICIPANT WINDOW of residual pages instead, sharded
# row-wise exactly like every other per-client stack — the specs are
# unchanged, only the array they partition shrank from fleet-sized to
# round-sized. The alias documents that the window intentionally shares the
# payload triple's layout (values / indices rows + per-row counts).
CLIENT_WINDOW_SPECS = CLIENT_PAYLOAD_SPECS


def payload_specs(wire_format):
    """PartitionSpec tuple for one wire payload (stored counts excluded):
    every component is per-client rows, so each device quantizes/packs and
    decodes only its local shard — neither CSR format adds a collective.

    ``"csr"``  -> ((K, cap) values, (K, cap) column indices)
    ``"csr_q"`` -> ((K, cap) int8 qvalues, (K, cap) int16 offsets,
                    (K, nblk) int16 block counts, (K,) f32 scales)
    """
    if wire_format == "csr_q":
        return (CLIENT_STACK_SPEC, CLIENT_STACK_SPEC, CLIENT_STACK_SPEC,
                CLIENT_VEC_SPEC)
    return (CLIENT_STACK_SPEC, CLIENT_STACK_SPEC)
# versioned base store (staleness-windowed delta chain): the (tau+2, N)
# reconstruction ring is tiny and REPLICATED on every device, while the
# per-client ring-slot index vector shards like any other per-client scalar
# — so the version-indexed base gather ``ring[slots]`` runs shard-local
# inside the round stages with no collective, replacing the dense (M, N)
# per-client row gather the legacy base store needed
RING_SPEC = P(None, None)
RING_SLOT_SPEC = CLIENT_VEC_SPEC


def client_mesh(num_devices=None) -> Mesh:
    """1D device mesh over the ``clients`` axis.

    The fleet engine shards stacked per-client state (rows of the (K, N)
    flat matrices) across devices; on a CPU host
    ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` provides D
    simulated devices. A mesh of one device degenerates to the batched
    engine's layout and is always valid.
    """
    devs = jax.devices()
    n = len(devs) if num_devices is None else min(num_devices, len(devs))
    return Mesh(np.asarray(devs[:n]), (CLIENT_AXIS,))


def padded_rows(k: int, num_shards: int) -> int:
    """Smallest multiple of ``num_shards`` >= k (>= 1 shard row each).

    shard_map input dims must divide the mesh axis exactly, so a round with
    K participants on D devices runs on ceil(K/D)*D rows; the pad rows carry
    zero validity masks / zero aggregation weight and are sliced off before
    any accounting.
    """
    k = max(int(k), 1)
    return ((k + num_shards - 1) // num_shards) * num_shards


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
_RULES_2D = {
    "embed": ("data", "model"),
    "lm_head": ("data", "model"),
    "vision_proj": ("data", None),
    "pos": (None, "data"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w_up": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_down": ("model", "data"),
    "router": ("data", None),
    "wq_a": ("data", None),
    "wq_b": (None, "model"),
    "wkv_a": ("data", None),
    "wk_b": (None, "model"),
    "wv_b": (None, "model"),
    "in_proj": ("data", "model"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "A_log": ("model", None),
    "conv_w": (None, "model"),
    "out_proj": ("model", "data"),
    "up": ("data", "model"),
    "down": ("model", "data"),
    "w": ("data", None),
}
_RULES_1D = {
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "conv_b": ("model",),
    "dt_bias": ("model",),
    "D": ("model",),
}
_RULES_3D = {
    "w_up": ("model", "data", None),     # MoE experts on model axis
    "w_gate": ("model", "data", None),
    "w_down": ("model", None, "data"),
}
_RULES_4D = {
    "r": (None, "model", None, None),
}

_FSDP_ONLY = "data"   # the axis fsdp=False strips from param specs


def _param_rule(name, shape, fsdp, profile="fsdp"):
    nd = len(shape)
    rule = None
    if nd == 3 and name in _RULES_3D:
        rule = _RULES_3D[name]
    elif nd == 4 and name in _RULES_4D:
        rule = _RULES_4D[name]
    elif nd == 2 and name in _RULES_2D:
        rule = _RULES_2D[name]
    elif nd == 1 and name in _RULES_1D:
        rule = _RULES_1D[name]
    if rule is None:
        return (None,) * nd
    if profile == "serve2d":
        # Inference profile: never shard a CONTRACTION/input dim over data
        # (that forces a full weight all-gather per step). Instead stack the
        # data axis onto the already-sharded output/feature dim (2D weight
        # sharding): matmul outputs come out sharded; XLA moves activation-
        # sized collectives, not weight-sized ones. Only plain matmul weights
        # get the stacking — MLA lora up-projections are reshaped to
        # (rank, H, head_dim) inside the layer, and GSPMD falls back to full
        # replication when the flat sharded dim splits across that reshape
        # (measured: 11 GB/layer involuntary remat traffic).
        # (Restricting the stacking to "safe" names was tried and REFUTED:
        # reverting MLA lora weights to model-only sharding brought back
        # 22 GB/token of all-gathers — worse than the reshape-replication it
        # avoided. See EXPERIMENTS.md §Perf case B it2.)
        out = []
        for a in rule:
            if a == _FSDP_ONLY:
                out.append(None)
            elif a == "model":
                out.append(("model", "data"))
            else:
                out.append(a)
        return tuple(out)
    if not fsdp:
        rule = tuple(None if a == _FSDP_ONLY else a for a in rule)
    return rule


def _is_stacked(path_keys):
    return any(k == "scan" for k in path_keys)


def _path_keys(path):
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "idx", None)
        if k is not None:
            out.append(k)
    return out


def param_specs(cfg: ModelConfig, params_shape, axis_sizes, *, fsdp=True,
                profile="fsdp"):
    """PartitionSpec pytree matching ``params_shape`` (from jax.eval_shape).

    profile="fsdp": train default (storage sharded over data, gathered on use)
    profile="serve2d": inference — 2D output-dim sharding, no weight gathers
    """

    def rule(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        shape = leaf.shape
        stacked = _is_stacked(keys)
        if stacked:
            shape = shape[1:]
        spec = _param_rule(name, shape, fsdp, profile)
        fitted = _fit(spec, shape, axis_sizes)
        if stacked:
            fitted = P(None, *fitted)
        return fitted

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_specs(cfg: ModelConfig, opt_shape, pspecs):
    return {"m": pspecs, "v": pspecs, "t": P()}


# ---------------------------------------------------------------------------
# activation / batch rules
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, batch_shape, axis_sizes):
    ba = batch_axes(axis_sizes)

    def rule(leaf):
        if leaf.ndim == 0:
            return P()
        return _fit((ba,) + (None,) * (leaf.ndim - 1), leaf.shape, axis_sizes)

    return jax.tree.map(rule, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, axis_sizes, *, batch_size):
    """Decode cache sharding.

    Attention caches (B, S, H, hd): batch over (pod, data) when divisible;
    KV heads over model when divisible, otherwise the sequence dim takes the
    model axis (GQA kv=8 on a 16-way model axis). batch=1 long-context decode
    shards the sequence over (data, model).
    """
    ba = batch_axes(axis_sizes)
    n_batch = 1
    for a in ba:
        n_batch *= axis_sizes[a]
    seq_shard = batch_size < n_batch

    def rule(path, leaf):
        keys = _path_keys(path)
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        stacked = _is_stacked(keys)
        shape = leaf.shape[1:] if stacked else leaf.shape
        nd = len(shape)
        spec = [None] * nd

        if name in ("k", "v", "cross_k", "cross_v") and nd == 4:
            H = shape[2]
            if seq_shard:
                spec = [None, ("data", "model"), None, None]
            elif H % axis_sizes.get("model", 1) == 0:
                spec = [ba, None, "model", None]
            else:
                spec = [ba, "model", None, None]
        elif name in ("ckv", "krope") and nd == 3:
            spec = [None, ("data", "model"), None] if seq_shard else [ba, "model", None]
        elif name == "ssm" and nd == 3:
            spec = [None if seq_shard else ba, "model", None]
        elif name == "conv" and nd == 3:
            spec = [None if seq_shard else ba, None, "model"]
        elif name in ("C", "n") and nd >= 2:
            spec = [None if seq_shard else ba, "model"] + [None] * (nd - 2)
        elif name in ("m", "c") and nd >= 2:
            spec = [None if seq_shard else ba, "model"] + [None] * (nd - 2)
        elif nd >= 1:
            spec = [None if seq_shard else ba] + [None] * (nd - 1)

        fitted = _fit(tuple(spec), shape, axis_sizes)
        return P(None, *fitted) if stacked else fitted

    return jax.tree_util.tree_map_with_path(rule, cache_shape)
