"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: one pod = (data=16, model=16) = 256 chips;
    multi-pod adds a leading pod axis: (pod=2, data=16, model=16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run through repro.launch.dryrun which forces 512 host devices")
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    arr = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices tests forced."""
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    arr = np.array(devices).reshape(shape)
    return jax.sharding.Mesh(arr, axes)
