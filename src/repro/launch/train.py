"""Training launcher.

Two modes:
  fl   — the paper: FedS3A over the synthetic CIC-IDS-2017 scenarios, with
         periodic checkpointing of the full server state.
  lm   — single-host LM pretraining driver for any assigned architecture
         (reduced configs run on CPU; full configs need the TPU mesh).

  PYTHONPATH=src python -m repro.launch.train fl --scenario basic --rounds 10
  PYTHONPATH=src python -m repro.launch.train lm --arch qwen2-1.5b --steps 5
"""
from __future__ import annotations

import argparse
import time

import jax


def run_fl(args):
    from repro.checkpoint import save_checkpoint
    from repro.core import FedS3AConfig, FedS3ATrainer
    from repro.data import make_dataset

    data = make_dataset(args.scenario, scale=args.scale, seed=args.seed)
    cfg = FedS3AConfig(rounds=args.rounds, C=args.C, tau=args.tau,
                       seed=args.seed)
    tr = FedS3ATrainer(data, cfg)
    for r in range(args.rounds):
        log = tr.run_round()
        m = tr.evaluate()
        print(f"round {log.round:3d} art={log.art:6.1f}s acc={m['accuracy']:.4f} "
              f"f1={m['f1']:.4f} participants={log.participants}")
        if args.ckpt and (r + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {
                "global_params": tr.global_params,
                "server_opt": tr.server_opt,
                "participation": tr.participation,
                "round": tr.global_version,
            })
            print(f"  checkpoint -> {args.ckpt}")
    final = tr.evaluate()
    print(f"final acc={final['accuracy']:.4f} aco={tr.comm.aco:.2f}")


def run_lm(args):
    from repro.configs import get_config
    from repro.models import lm
    from repro.optimizer import adam_init
    from repro.training.steps import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, rng)
    opt = adam_init(params)
    step = jax.jit(make_train_step(cfg, lr=args.lr,
                                   num_microbatches=args.microbatches,
                                   impl="ref" if args.reduced else "flash"))
    B, S = args.batch, args.seq
    for i in range(args.steps):
        rng, k = jax.random.split(rng)
        batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                k, (B, cfg.num_encoder_positions, cfg.d_model))
        if cfg.num_vision_patches:
            batch["patches"] = jax.random.normal(
                k, (B, cfg.num_vision_patches, cfg.d_model))
        t0 = time.time()
        params, opt, loss = step(params, opt, batch)
        print(f"step {i}: loss={float(loss):.4f} ({time.time()-t0:.2f}s)")


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    fl = sub.add_parser("fl")
    fl.add_argument("--scenario", default="basic",
                    choices=["basic", "balanced"])
    fl.add_argument("--rounds", type=int, default=10)
    fl.add_argument("--scale", type=float, default=0.01)
    fl.add_argument("--C", type=float, default=0.6)
    fl.add_argument("--tau", type=int, default=2)
    fl.add_argument("--seed", type=int, default=0)
    fl.add_argument("--ckpt", default=None)
    fl.add_argument("--ckpt-every", type=int, default=5)

    lm_ = sub.add_parser("lm")
    lm_.add_argument("--arch", default="qwen2-1.5b")
    lm_.add_argument("--steps", type=int, default=5)
    lm_.add_argument("--batch", type=int, default=2)
    lm_.add_argument("--seq", type=int, default=128)
    lm_.add_argument("--lr", type=float, default=3e-4)
    lm_.add_argument("--microbatches", type=int, default=1)
    lm_.add_argument("--reduced", action="store_true", default=True)
    lm_.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    if args.mode == "fl":
        run_fl(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
