"""Per-(architecture x input-shape) dry-run case construction.

``build_case`` returns everything the dry-run / roofline harness needs:
the step function, ShapeDtypeStruct arguments (no allocation!), and the
in_shardings pytrees for the production mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, INPUT_SHAPES, ModelConfig
from repro.distributed.sharding import (
    batch_axes, batch_specs, cache_specs, mesh_axis_sizes, opt_specs,
    param_specs)
from repro.models import lm
from repro.optimizer import adam_init
from repro.training.steps import (
    make_forward_step, make_serve_step, make_train_step)

P = jax.sharding.PartitionSpec


def _sds(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def has_full_attention(cfg: ModelConfig) -> bool:
    """Any attention layer without a sliding window?"""
    kinds = [cfg.block_kind(i) for i in range(cfg.num_layers)]
    return ATTN in kinds and cfg.window is None


def uses_window(cfg: ModelConfig, seq_len: int) -> bool:
    return cfg.window is not None and seq_len > 32_768


@dataclass
class Case:
    name: str
    cfg: ModelConfig
    step_fn: Any
    args: tuple
    in_shardings: tuple
    kind: str
    notes: str = ""


def build_case(cfg: ModelConfig, shape_name: str, mesh, *,
               fsdp: bool = True, moe_impl: str = "einsum",
               attn_impl: str = "flash", seq_parallel: bool = False,
               lr: float = 3e-4, capacity_factor: float = 1.25,
               serve_profile: str = "fsdp") -> Case:
    shape = INPUT_SHAPES[shape_name]
    axis_sizes = mesh_axis_sizes(mesh)
    ba = batch_axes(axis_sizes)
    n_batch_shards = 1
    for a in ba:
        n_batch_shards *= axis_sizes[a]

    # MoE token groups track the batch shards (GShard grouping)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe_groups=n_batch_shards)

    B = shape.global_batch
    S = shape.seq_len
    text_len = S - cfg.num_vision_patches if cfg.num_vision_patches else S

    def mk_batch():
        b = {"tokens": jax.ShapeDtypeStruct((B, text_len), jnp.int32)}
        if cfg.num_vision_patches:
            b["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_vision_patches, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            b["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.num_encoder_positions, cfg.d_model), jnp.bfloat16)
        return b

    params_shape = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_specs(cfg, params_shape, axis_sizes, fsdp=fsdp)

    if shape.kind == "train":
        nm = max(B // n_batch_shards, 1)
        step = make_train_step(cfg, lr=lr, num_microbatches=nm,
                               impl=attn_impl, moe_impl=moe_impl,
                               seq_parallel=seq_parallel)
        opt_shape = jax.eval_shape(
            lambda: adam_init(params_shape, dtype=jnp.dtype(cfg.opt_state_dtype)))
        batch = mk_batch()
        args = (params_shape, opt_shape, batch)
        shardings = (pspecs, opt_specs(cfg, opt_shape, pspecs),
                     batch_specs(cfg, batch, axis_sizes))
        notes = f"microbatches={nm} fsdp={fsdp} seq_parallel={seq_parallel}"
        return Case(f"{cfg.name}:{shape_name}", cfg, step, args, shardings,
                    "train", notes)

    # inference: serve in bf16 params
    icfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    params_shape = jax.eval_shape(lambda: lm.init_params(icfg, jax.random.PRNGKey(0)))
    pspecs = param_specs(icfg, params_shape, axis_sizes, fsdp=fsdp,
                         profile=serve_profile)

    if shape.kind == "prefill":
        step = make_forward_step(icfg, impl=attn_impl, moe_impl=moe_impl,
                                 seq_parallel=seq_parallel)
        batch = mk_batch()
        args = (params_shape, batch)
        shardings = (pspecs, batch_specs(icfg, batch, axis_sizes))
        return Case(f"{cfg.name}:{shape_name}", icfg, step, args, shardings,
                    "prefill", f"fsdp={fsdp}")

    # decode
    ring = uses_window(icfg, S)
    cache_len = icfg.window if ring else S
    notes = f"ring_window={icfg.window}" if ring else f"full_cache={S}"
    cache_shape = jax.eval_shape(lambda: lm.init_cache(icfg, B, cache_len))
    cspecs = cache_specs(icfg, cache_shape, axis_sizes, batch_size=B)
    step = make_serve_step(icfg, ring=ring, moe_impl=moe_impl)
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = P(ba) if B % max(n_batch_shards, 1) == 0 and B >= n_batch_shards else P()
    args = (params_shape, cache_shape, token, index)
    shardings = (pspecs, cspecs, tok_spec, P())
    return Case(f"{cfg.name}:{shape_name}", icfg, step, args, shardings,
                "decode", notes + f" fsdp={fsdp}")
