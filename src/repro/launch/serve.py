"""Serving launcher: batched request loop over prefill + decode.

Requests (prompt token lists) are batched, padded to the bucket size,
prefilled once, then decoded greedily with the arch's cache flavour
(KV / MLA latent / mamba / xLSTM state). Reduced configs on CPU; the same
serve_step lowers for decode_32k / long_500k on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 6
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.training.steps import make_serve_step


def serve_batch(cfg, params, prompts, *, max_new, bucket):
    """prompts: list[list[int]] -> list[list[int]] continuations."""
    B = len(prompts)
    K = max(len(p) for p in prompts)
    K = min(bucket, max(K, 1))
    toks = np.zeros((B, K), np.int32)
    for i, p in enumerate(prompts):
        toks[i, -len(p):] = p[:K]                # left-pad into the bucket
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros((B, cfg.num_encoder_positions, cfg.d_model))
    if cfg.num_vision_patches:
        batch["patches"] = jnp.zeros((B, cfg.num_vision_patches, cfg.d_model))
    P = cfg.num_vision_patches or 0

    last, cache = jax.jit(
        lambda pr, b: lm.prefill(cfg, pr, b, K + max_new + P))(params, batch)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        tok, _, cache = serve(params, cache, tok, jnp.int32(P + K + i))
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--bucket", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, rng)

    rngs = np.random.default_rng(args.seed)
    prompts = [list(rngs.integers(0, cfg.vocab_size,
                                  rngs.integers(4, args.bucket)))
               for _ in range(args.requests)]
    print(f"arch={args.arch} (reduced) — {len(prompts)} requests, "
          f"bucket={args.bucket}, max_new={args.max_new}")
    t0 = time.time()
    outs = serve_batch(cfg, params, prompts, max_new=args.max_new,
                       bucket=args.bucket)
    dt = time.time() - t0
    for i, o in enumerate(outs[:3]):
        print(f"  request {i} ({len(prompts[i])} prompt toks) -> {o.tolist()}")
    print(f"{args.requests * args.max_new} tokens in {dt:.2f}s "
          f"({args.requests * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
