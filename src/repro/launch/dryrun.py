import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, print memory/cost analysis, and emit roofline terms.

MUST be executed as its own process (`python -m repro.launch.dryrun ...`)
because the device-count flag above has to land before jax initializes.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh pod --out results.json
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.analysis import roofline as RL                     # noqa: E402
from repro.configs import INPUT_SHAPES, get_config, list_configs  # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.specs import build_case                     # noqa: E402
from repro.distributed.sharding import jit_shardings, use_mesh  # noqa: E402


def run_one(arch, shape_name, *, multi_pod=False, fsdp=True, moe_impl="einsum",
            attn_impl="flash", seq_parallel=False, verbose=True,
            capacity_factor=1.25, serve_profile="fsdp"):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    case = build_case(cfg, shape_name, mesh, fsdp=fsdp,
                      moe_impl=moe_impl, attn_impl=attn_impl,
                      seq_parallel=seq_parallel, capacity_factor=capacity_factor,
                      serve_profile=serve_profile)
    t0 = time.time()
    with use_mesh(mesh):
        jitted = jax.jit(case.step_fn,
                         in_shardings=jit_shardings(mesh, case.in_shardings))
        lowered = jitted.lower(*case.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    shape = INPUT_SHAPES[shape_name]
    rl = RL.analyze(case.name, compiled,
                    model_flops=RL.model_flops_per_step(cfg, shape), chips=chips)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": case.kind,
        "notes": case.notes,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": rl.flops,
            "hbm_bytes": rl.hbm_bytes,
            "collective_bytes": rl.coll_bytes,
            "collectives": {k: v for k, v in rl.coll_breakdown.items() if v},
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "roofline": {
            "t_compute_ms": rl.t_compute * 1e3,
            "t_memory_ms": rl.t_memory * 1e3,
            "t_collective_ms": rl.t_collective * 1e3,
            "bottleneck": rl.bottleneck,
            "model_flops": rl.model_flops,
            "useful_flops_ratio": rl.useful_flops_ratio,
        },
    }
    if verbose:
        print(f"== {case.name} on {rec['mesh']} ({case.kind}; {case.notes})")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: args={rec['per_device']['argument_bytes']} "
              f"out={rec['per_device']['output_bytes']} "
              f"temp={rec['per_device']['temp_bytes']}")
        print(f"   cost_analysis: flops/dev={rl.flops:.3e} hbm/dev={rl.hbm_bytes:.3e}")
        print(f"   collectives/dev: {rec['per_device']['collectives']}")
        print(f"   roofline ms: compute={rl.t_compute*1e3:.2f} "
              f"memory={rl.t_memory*1e3:.2f} collective={rl.t_collective*1e3:.2f} "
              f"-> {rl.bottleneck}  useful_flops_ratio={rl.useful_flops_ratio:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--moe-impl", default="einsum", choices=["einsum", "sort"])
    ap.add_argument("--attn-impl", default="flash", choices=["flash", "ref"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if args.all or not args.arch else [args.arch]
    archs = [a for a in archs if a != "feds3a-cnn"]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_one(
                        arch, shape, multi_pod=mp, fsdp=not args.no_fsdp,
                        moe_impl=args.moe_impl, attn_impl=args.attn_impl,
                        seq_parallel=args.seq_parallel))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    print(f"\n{len(results)} ok, {len(failures)} failed")
    for f_ in failures:
        print("FAIL:", f_)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
