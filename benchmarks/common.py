"""Shared benchmark scaffolding for the paper-table reproductions.

Fast mode (default for `python -m benchmarks.run`) uses a reduced data scale
and fewer rounds so the whole suite completes on one CPU core; --full uses
scale 0.01 / 12 rounds / both scenarios per table (closer to the paper's
resolution). Trends, not absolute third-decimal values, are the reproduction
target (synthetic data; see DESIGN.md §8).
"""
from __future__ import annotations

import functools
import time

from repro.core import FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset

FAST = {"scale": 0.006, "rounds": 8, "scenarios": ("basic",)}
FULL = {"scale": 0.01, "rounds": 12, "scenarios": ("basic", "balanced")}


@functools.lru_cache(maxsize=8)
def dataset(scenario, scale, server_frac=0.05, seed=0):
    return make_dataset(scenario, scale=scale, server_frac=server_frac,
                        seed=seed)


def run_feds3a(scenario, *, scale, rounds, seed=0, server_frac=0.05,
               **cfg_overrides):
    data = dataset(scenario, scale, server_frac, seed)
    cfg = FedS3AConfig(rounds=rounds, seed=seed, **cfg_overrides)
    t0 = time.time()
    tr = FedS3ATrainer(data, cfg)
    res = tr.train()
    res["wall_s"] = time.time() - t0
    return res


def fmt_row(name, res):
    m = res["metrics"]
    return (f"{name:36s} acc={m['accuracy']:.4f} prec={m['precision']:.4f} "
            f"rec={m['recall']:.4f} f1={m['f1']:.4f} fpr={m['fpr']:.4f} "
            f"art={res['art']:.1f} aco={res['aco']:.2f}")


def csv_row(table, scenario, name, res):
    m = res["metrics"]
    return (f"{table},{scenario},{name},{m['accuracy']:.4f},{m['precision']:.4f},"
            f"{m['recall']:.4f},{m['f1']:.4f},{m['fpr']:.4f},"
            f"{res['art']:.1f},{res['aco']:.3f},{res['wall_s']:.0f}")


CSV_HEADER = ("table,scenario,variant,accuracy,precision,recall,f1,fpr,"
              "art_s,aco,wall_s")
