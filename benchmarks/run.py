"""Benchmark aggregator — one module per paper table (V-XII), plus kernel
microbenchmarks, the round-engine benchmark and the roofline summary.

  PYTHONPATH=src python -m benchmarks.run            # fast mode
  PYTHONPATH=src python -m benchmarks.run --full     # paper-resolution
  PYTHONPATH=src python -m benchmarks.run --only T5,T12
  PYTHONPATH=src python -m benchmarks.run --json     # + machine-readable dump
"""
import argparse
import sys
import time

from benchmarks import (bench_kernels, bench_roofline, bench_round,
                        table05_staleness_fns, table06_round_weight_fns,
                        table07_staleness_tolerance, table08_participation,
                        table09_server_data, table10_group_agg,
                        table11_dynamic_weight, table12_comparison)
from benchmarks.common import CSV_HEADER, FAST, FULL

TABLES = {
    "T5": table05_staleness_fns,
    "T6": table06_round_weight_fns,
    "T7": table07_staleness_tolerance,
    "T8": table08_participation,
    "T9": table09_server_data,
    "T10": table10_group_agg,
    "T11": table11_dynamic_weight,
    "T12": table12_comparison,
    "kernels": bench_kernels,
    "round": bench_round,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated table ids (e.g. T5,T12,kernels)")
    ap.add_argument("--csv", default="results/benchmarks.csv")
    ap.add_argument("--json", action="store_true",
                    help="also dump machine-readable results (CSV rows as "
                         "JSON records next to --csv; bench_round always "
                         "writes BENCH_round.json)")
    args = ap.parse_args()

    mode = FULL if args.full else FAST
    names = list(TABLES) if not args.only else args.only.split(",")

    out = [CSV_HEADER]
    t0 = time.time()
    for name in names:
        if name not in TABLES:
            print(f"unknown table {name}", file=sys.stderr)
            continue
        print(f"===== {name} ({TABLES[name].__doc__.splitlines()[0]})")
        t1 = time.time()
        TABLES[name].run(mode, out)
        print(f"----- {name} done in {time.time()-t1:.0f}s\n")

    if args.csv:
        import os
        os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
        with open(args.csv, "w") as f:
            f.write("\n".join(out) + "\n")
        print(f"CSV -> {args.csv}")
    if args.json:
        import json
        import os
        header = out[0].split(",")
        records = []
        for row in out[1:]:
            vals = row.split(",")
            if len(vals) == len(header):
                records.append(dict(zip(header, vals)))
            else:   # kern/roofline rows use their own layouts
                records.append({"table": vals[0], "raw": row})
        json_path = (os.path.splitext(args.csv)[0] + ".json") if args.csv \
            else "results/benchmarks.json"
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(records, f, indent=2)
        print(f"JSON -> {json_path}")
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
