"""Round-engine benchmark: batched vs sequential FedS3A round loop.

Measures steady-state per-round wall time of the two round engines on the
SAME schedule/seed, interleaving their rounds (A/B/A/B...) so machine noise
hits both alike, and reports medians. Warm-up rounds absorb XLA compilation.

Fast mode is an *engine* benchmark: M=10 clients, 5 timed rounds, a
reduced-width CNN (same architecture as the paper's §V-B net) and small
per-client datasets, so per-round wall time is dominated by the round
machinery the batched engine eliminates — per-client dispatch, per-message
encode chains and host syncs — rather than by GEMMs that are identical in
both engines. --full times the paper-size CNN as well (the compute-bound
regime, where the engines are expected to roughly tie on CPU).

Also verifies parity (same accuracy / ACO / participation from the same
seed) and writes machine-readable results to BENCH_round.json.

  PYTHONPATH=src python -m benchmarks.bench_round            # fast mode
  PYTHONPATH=src python -m benchmarks.bench_round --full
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset

# reduced-width instance of the paper's CNN for the engine-dominated regime
BENCH_CNN = CNNConfig(name="feds3a-cnn-bench", conv_filters=(8, 8), hidden=16)

FAST_CASE = dict(name="engine(bench-cnn)", scale=0.0015, cnn=BENCH_CNN,
                 C=0.8, batch_size=50)
FULL_CASE = dict(name="paper-cnn", scale=0.006, cnn=None, C=0.6,
                 batch_size=100)


def _sync(tr):
    jax.block_until_ready(tr._global_flat if tr.batched
                          else tr.global_params)


def bench_case(*, name, scale, cnn, C, batch_size, rounds=5, warmup=3,
               seed=0):
    data = make_dataset("basic", scale=scale, seed=seed)

    def mk(batched):
        return FedS3ATrainer(data, FedS3AConfig(
            rounds=rounds + warmup, seed=seed,
            engine="batched" if batched else "sequential", cnn=cnn,
            C=C, batch_size=batch_size))

    seq, bat = mk(False), mk(True)
    for _ in range(warmup):
        seq.run_round()
        bat.run_round()
    _sync(seq), _sync(bat)

    seq_t, bat_t = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        seq.run_round()
        _sync(seq)
        seq_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        bat.run_round()
        _sync(bat)
        bat_t.append(time.perf_counter() - t0)

    m_seq, m_bat = seq.evaluate(), bat.evaluate()
    res = {
        "case": name,
        "clients": seq.M,
        "rounds_timed": rounds,
        "sequential_s_per_round": float(np.median(seq_t)),
        "batched_s_per_round": float(np.median(bat_t)),
        "speedup": float(np.median(seq_t) / np.median(bat_t)),
        "parity": {
            "accuracy_sequential": m_seq["accuracy"],
            "accuracy_batched": m_bat["accuracy"],
            "aco_sequential": seq.comm.aco,
            "aco_batched": bat.comm.aco,
            "participation_identical": bool(
                np.array_equal(seq.participation, bat.participation)),
        },
    }
    return res


def run(mode, out, json_path="BENCH_round.json"):
    """Benchmark table hook (same shape as the tableXX modules)."""
    cases = [FAST_CASE] if mode.get("scenarios") == ("basic",) \
        else [FAST_CASE, FULL_CASE]
    results = [bench_case(**c) for c in cases]
    for r in results:
        line = (f"round-engine {r['case']:20s} "
                f"seq {r['sequential_s_per_round']*1e3:8.1f} ms/round  "
                f"batched {r['batched_s_per_round']*1e3:8.1f} ms/round  "
                f"speedup {r['speedup']:.2f}x  parity "
                f"{'ok' if r['parity']['participation_identical'] else 'FAIL'}")
        print(line)
        out.append(f"round,{r['case']},batched_vs_sequential,"
                   f"{r['parity']['accuracy_batched']:.4f},,,,,"
                   f",{r['parity']['aco_batched']:.3f},"
                   f"{r['batched_s_per_round']:.3f}")
    with open(json_path, "w") as f:
        json.dump({"results": results}, f, indent=2)
    print(f"JSON -> {json_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also time the paper-size CNN (compute-bound)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--json", default="BENCH_round.json")
    args = ap.parse_args()

    cases = [FAST_CASE] + ([FULL_CASE] if args.full else [])
    results = []
    for c in cases:
        c = dict(c)
        r = bench_case(**c, rounds=args.rounds)
        results.append(r)
        print(f"{r['case']}: sequential "
              f"{r['sequential_s_per_round']*1e3:.1f} ms/round, batched "
              f"{r['batched_s_per_round']*1e3:.1f} ms/round -> "
              f"{r['speedup']:.2f}x speedup "
              f"(parity: acc {r['parity']['accuracy_batched']:.4f} vs "
              f"{r['parity']['accuracy_sequential']:.4f}, aco "
              f"{r['parity']['aco_batched']:.3f} vs "
              f"{r['parity']['aco_sequential']:.3f}, participation "
              f"{'identical' if r['parity']['participation_identical'] else 'DIFFERS'})")
    with open(args.json, "w") as f:
        json.dump({"results": results}, f, indent=2)
    print(f"JSON -> {args.json}")


if __name__ == "__main__":
    main()
