"""CI perf-regression gate for the fleet benchmark.

Diffs a fresh smoke run of ``benchmarks.bench_fleet`` against the committed
baseline (BENCH_fleet.json) cell by cell — cells are keyed by
(clients, devices, error_feedback, base_store, faults, wire_format,
client_store, model, checkpoint) — and fails the job when:

* throughput regresses by more than ``--max-slowdown`` (default 30%) on
  the GEOMETRIC MEAN across cells, or by more than twice that on any
  single cell. Single-cell rounds/sec on shared CI runners is noisy
  (measured +/-30% cell-to-cell on a loaded 2-core host while bytes stayed
  bit-identical), so the aggregate catches structural regressions — an
  accidental host sync, a lost jit cache — without flaking on scheduler
  jitter; the per-cell floor still catches a regression confined to one
  configuration, or
* bytes-on-wire per round grow beyond ``--bytes-tol`` (default 2%; smoke
  and baseline time the same rounds from the same seed, so the comparison
  is deterministic up to quantile-threshold float flips — measured x1.000 —
  and any real increase means the compaction got worse and trips the
  gate), or
* the residual store stopped being smaller than its dense equivalent on
  the error-feedback cells, or
* the base-store memory gate fails: a versioned-store cell's
  ``base_store_bytes`` must stay strictly below the dense O(M*N)
  equivalent at every committed fleet size, and wherever a (K, D) pair has
  both a versioned and a ``base_store="dense"`` cell, the versioned cell
  must also put strictly fewer bytes on the wire (its distribution is a
  chain-delta broadcast instead of per-target encodes), or
* the round-efficiency gate fails on a fault-injected cell: under the
  committed churn profile (crash/loss/churn + deadline), the mean quorum
  fraction — uploads aggregated per round over the participation target k
  — must not drop more than ``--quorum-tol`` (absolute, default 0.05)
  below the committed baseline. The fault trace is seed-deterministic, so
  a drop means a scheduler change made degraded rounds worse, not noise, or
* the quantized-wire gate fails: wherever a (K, D) pair has both a
  ``wire_format="csr_q"`` cell and its f32 ``"csr"`` twin (same EF /
  store / faults), the csr_q cell must put on the wire at most 0.4x the
  twin's payload bytes per round (int8 values + packed int16 offsets are
  3 bytes per stored element vs the twin's 8), keep at least 0.9x the
  twin's rounds/sec (the dequantizing scatter must stay fused, not a
  separate pass), and land within 1e-2 of the twin's final accuracy (the
  EF residual absorbs the rounding error; a larger gap means the
  quantization stopped being error-compensated). Both cells come from the
  same run on the same host, so the throughput ratio is insulated from
  runner drift, or
* the client-state scale gate fails on a ``client_store="paged"`` cell:
  its ``client_state_device_bytes`` (the participant window + pending
  writeback pages) must stay strictly below
  ``client_state_resident_equiv_bytes`` (what the resident layout would
  hold on device at that M), its rounds/sec must stay >= 0.9x its resident
  twin from the SAME run at K <= 2048 (the page gather/scatter must
  overlap, not serialize), and — across ALL paged cells, including the
  M=1,000,000 scale cell — device bytes PER PARTICIPANT of the largest-M
  cell must stay within 4x the smallest-M cell's: the flat-in-M claim.
  (The 4x slop absorbs padded-batch-count variation between the pooled
  scale dataset and the per-K fleet datasets; a resident layout would blow
  past it by orders of magnitude at 1M clients.), or
* the checkpoint-overhead gate fails on a ``checkpoint=True`` cell:
  crash-consistent snapshots every ``checkpoint_every=5`` rounds
  (tmp-write + fsync + rename of every section, sha256 manifest commit)
  must keep at least 0.95x the rounds/sec of the cell's same-process
  no-checkpoint twin — checkpointing is supposed to cost <5% wall time —
  and the cell must actually have written at least one non-empty
  snapshot (a zero-byte or zero-save report means the cadence silently
  stopped firing, which would green-light a broken save path), or
* the chunked-memory scale gate fails on the large-model cells: across
  the ``model != "cnn"`` cells sharing one chunk_size (two reduced
  transformers whose parameter counts differ by >= 2x),
  ``peak_delta_device_bytes`` must grow at most HALF as fast as N
  (flat-in-N up to leaf-packing raggedness), and every chunked cell's
  peak must stay under the absolute ceiling ``24 * K * chunk_size``
  bytes — a bound set by the chunk width alone, independent of N. A flat
  (K, N) stage smuggled back into any round body blows both. The flat
  CNN cells are keyed ``model="cnn"`` (the default for pre-chunked
  baselines), so their comparisons are unchanged.

The throughput comparison is absolute rounds/sec against a baseline
measured on whatever machine last ran the full sweep — a systematically
slower runner fleet reads as a regression. That is deliberate (the gate
guards the committed numbers, and GitHub-hosted runners are homogeneous
enough for the 30% aggregate band), but if runner hardware shifts, rerun
``python -m benchmarks.bench_fleet`` on the new hardware and commit the
refreshed BENCH_fleet.json rather than loosening ``--max-slowdown``.

Exit code 0 = green, 1 = regression, 2 = unusable inputs.

  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline BENCH_fleet.json --candidate BENCH_fleet_smoke.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def _cells(path):
    with open(path) as f:
        payload = json.load(f)
    results = payload["results"] if isinstance(payload, dict) else payload
    out = {}
    for r in results:
        key = (r["clients"], r["devices"], bool(r.get("error_feedback")),
               r.get("base_store", "versioned"), bool(r.get("faults")),
               r.get("wire_format", "csr"),
               r.get("client_store", "resident"),
               r.get("model", "cnn"), bool(r.get("checkpoint")))
        out[key] = r
    return out


def compare(baseline, candidate, *, max_slowdown, bytes_tol, quorum_tol):
    failures, skipped, rows, speeds = [], [], [], []
    for key, cand in sorted(candidate.items()):
        base = baseline.get(key)
        k, d, ef, store, faults, wire, cstore, model, ckpt = key
        name = f"K={k} D={d}{' ef' if ef else ''}" + \
            (f" {store}" if store != "versioned" else "") + \
            (" faults" if faults else "") + \
            (f" {wire}" if wire != "csr" else "") + \
            (f" {cstore}" if cstore != "resident" else "") + \
            (f" {model}" if model != "cnn" else "") + \
            (" ckpt" if ckpt else "")
        # base-store memory gate: the versioned store must stay sublinear —
        # strictly below the dense (M, N) equivalent — at every committed
        # fleet size (candidate-only check, no baseline cell needed)
        if store == "versioned" and "base_store_bytes" in cand:
            if cand["base_store_bytes"] >= \
                    cand.get("base_store_dense_equiv_bytes", float("inf")):
                failures.append(
                    f"{name}: versioned base store "
                    f"{cand['base_store_bytes']} B is not smaller than the "
                    f"dense equivalent "
                    f"{cand['base_store_dense_equiv_bytes']} B")
            dense_twin = candidate.get((k, d, ef, "dense", faults, wire,
                                        cstore, model, ckpt))
            if dense_twin is not None:
                if cand["base_store_bytes"] >= \
                        dense_twin.get("base_store_bytes", float("inf")):
                    failures.append(
                        f"{name}: versioned base store is not smaller than "
                        f"the measured dense-store cell")
                if cand["payload_bytes_per_round"] >= \
                        dense_twin["payload_bytes_per_round"]:
                    failures.append(
                        f"{name}: versioned distribution lost its "
                        f"bytes-on-wire win — "
                        f"{cand['payload_bytes_per_round']:.0f}/round vs "
                        f"{dense_twin['payload_bytes_per_round']:.0f} with "
                        f"the dense store")
        # quantized-wire gate: a csr_q cell is judged against its f32 CSR
        # twin from the SAME run (same K/D/EF/store/faults, same host), so
        # the byte ratio is deterministic and the throughput ratio is
        # insulated from runner drift (candidate-only, no baseline needed)
        if wire == "csr_q":
            twin = candidate.get((k, d, ef, store, faults, "csr", cstore,
                                  model, ckpt))
            if twin is None:
                skipped.append(f"{name} (no f32 csr twin cell)")
            else:
                qwire = cand["payload_bytes_per_round"] / \
                    max(twin["payload_bytes_per_round"], 1e-9)
                qspeed = cand["rounds_per_sec"] / twin["rounds_per_sec"]
                qacc = abs(cand["final_accuracy"] - twin["final_accuracy"])
                rows.append(f"  {name:16s} vs f32 twin: bytes x{qwire:5.3f} "
                            f"rounds/s x{qspeed:5.2f} |d-acc| {qacc:.4f}")
                if qwire > 0.4:
                    failures.append(
                        f"{name}: quantized payload is x{qwire:.3f} of the "
                        f"f32 csr twin (gate: <=0.4 — int8+packed offsets "
                        f"should be ~3/8 of the f32 bytes)")
                if qspeed < 0.9:
                    failures.append(
                        f"{name}: quantized wire throughput is x{qspeed:.2f} "
                        f"of the f32 csr twin (gate: >=0.9)")
                if qacc > 1e-2:
                    failures.append(
                        f"{name}: final accuracy {cand['final_accuracy']:.4f}"
                        f" is {qacc:.4f} from the f32 csr twin's "
                        f"{twin['final_accuracy']:.4f} (gate: <=0.01)")
        # client-state scale gate: a paged cell must hold strictly less on
        # device than the resident layout would at its fleet size, and at
        # CI-sized fleets must stay within 0.9x of its resident twin's
        # throughput from the SAME run (candidate-only, no baseline needed)
        if cstore == "paged":
            dev = cand.get("client_state_device_bytes")
            req = cand.get("client_state_resident_equiv_bytes")
            if dev is not None and req is not None:
                rows.append(f"  {name:16s} device client state "
                            f"{dev/1e6:8.2f} MB (resident equiv "
                            f"{req/1e6:.2f} MB)")
                if dev >= req:
                    failures.append(
                        f"{name}: paged device client-state bytes {dev} are "
                        f"not smaller than the resident equivalent {req}")
            if k <= 2048:
                # prefer the same-process interleaved twin measurement the
                # paged cell carries — a separate resident worker's number
                # swings with between-process CPU state far more than the
                # gate's 10% budget
                tspeed = cand.get("resident_twin_rounds_per_sec")
                if not tspeed:
                    rtwin = candidate.get((k, d, ef, store, faults, wire,
                                           "resident", model, ckpt))
                    tspeed = rtwin["rounds_per_sec"] if rtwin else None
                if tspeed is None:
                    skipped.append(f"{name} (no resident twin cell)")
                else:
                    pspeed = cand["rounds_per_sec"] / tspeed
                    rows.append(f"  {name:16s} vs resident twin: "
                                f"rounds/s x{pspeed:5.2f}")
                    if pspeed < 0.9:
                        failures.append(
                            f"{name}: paged throughput is x{pspeed:.2f} of "
                            f"the resident twin (gate: >=0.9 — the page "
                            f"gather/scatter must overlap, not serialize)")
        # checkpoint-overhead gate: a checkpointing cell is judged against
        # its same-process no-checkpoint twin — atomic snapshots every
        # checkpoint_every rounds must cost <5% throughput, and at least
        # one non-empty snapshot must actually have been committed
        # (candidate-only, no baseline cell needed)
        if ckpt:
            tspeed = cand.get("no_ckpt_twin_rounds_per_sec")
            if not tspeed:
                ntwin = candidate.get((k, d, ef, store, faults, wire,
                                       cstore, model, False))
                tspeed = ntwin["rounds_per_sec"] if ntwin else None
            if tspeed is None:
                skipped.append(f"{name} (no no-checkpoint twin cell)")
            else:
                cspeed = cand["rounds_per_sec"] / tspeed
                rows.append(
                    f"  {name:16s} vs no-ckpt twin: rounds/s x{cspeed:5.2f} "
                    f"({cand.get('checkpoint_bytes', 0)/1e6:.2f} MB/snap, "
                    f"{cand.get('checkpoint_save_s_mean', 0)*1e3:.1f} "
                    f"ms/save)")
                if cspeed < 0.95:
                    failures.append(
                        f"{name}: checkpointing every "
                        f"{cand.get('checkpoint_every')} rounds costs "
                        f"x{cspeed:.2f} of the no-checkpoint twin's "
                        f"throughput (gate: >=0.95)")
            if not cand.get("checkpoint_saves") \
                    or not cand.get("checkpoint_bytes"):
                failures.append(
                    f"{name}: checkpoint cell committed no snapshot "
                    f"(saves={cand.get('checkpoint_saves')}, "
                    f"bytes={cand.get('checkpoint_bytes')}) — the save "
                    f"cadence stopped firing")
        if base is None:
            skipped.append(name)
            continue
        speed = cand["rounds_per_sec"] / base["rounds_per_sec"]
        speeds.append(speed)
        wire = cand["payload_bytes_per_round"] / \
            max(base["payload_bytes_per_round"], 1e-9)
        rows.append(f"  {name:16s} rounds/s x{speed:5.2f}  "
                    f"bytes-on-wire x{wire:5.3f}")
        if speed < 1.0 - 2 * max_slowdown:
            failures.append(
                f"{name}: throughput {cand['rounds_per_sec']:.3f} rounds/s "
                f"is {(1 - speed) * 100:.0f}% below baseline "
                f"{base['rounds_per_sec']:.3f} "
                f"(per-cell floor: {2 * max_slowdown:.0%})")
        if wire > 1.0 + bytes_tol:
            failures.append(
                f"{name}: bytes-on-wire {cand['payload_bytes_per_round']:.0f}"
                f"/round exceed baseline "
                f"{base['payload_bytes_per_round']:.0f} by "
                f"{(wire - 1) * 100:.1f}% (gate: {bytes_tol:.0%})")
        if faults:
            # round-efficiency gate: same seed → same fault trace, so any
            # quorum drop is a real scheduler/degradation regression
            bq = base.get("mean_quorum_frac")
            cq = cand.get("mean_quorum_frac")
            if bq is not None and cq is not None:
                rows.append(f"  {name:16s} quorum {cq:.3f} "
                            f"(baseline {bq:.3f})")
                if cq < bq - quorum_tol:
                    failures.append(
                        f"{name}: mean quorum fraction {cq:.3f} dropped "
                        f"more than {quorum_tol:.2f} below baseline "
                        f"{bq:.3f} — degraded rounds got worse")
        if ef and cand.get("residual_store_bytes", 0) >= \
                cand.get("residual_dense_equiv_bytes", float("inf")):
            failures.append(
                f"{name}: residual store "
                f"{cand['residual_store_bytes']} B is not smaller than the "
                f"dense equivalent {cand['residual_dense_equiv_bytes']} B")
    # flat-in-M gate: across every paged cell (the CI-sized fleets AND the
    # M=1,000,000 scale cell), device client-state bytes per participant
    # must not grow with the fleet — a resident layout smuggled back in
    # would blow the largest-M cell up by orders of magnitude
    paged = [c for key, c in candidate.items()
             if key[6] == "paged" and c.get("client_state_device_bytes")
             and c.get("participants_per_round")]
    if len(paged) >= 2:
        per = sorted((c["clients"],
                      c["client_state_device_bytes"]
                      / c["participants_per_round"]) for c in paged)
        (m_lo, b_lo), (m_hi, b_hi) = per[0], per[-1]
        rows.append(f"  paged device bytes/participant: {b_lo:.0f} at "
                    f"M={m_lo} -> {b_hi:.0f} at M={m_hi}")
        if b_hi > 4 * b_lo:
            failures.append(
                f"paged client state is not flat in M: "
                f"{b_hi:.0f} B/participant at M={m_hi} vs {b_lo:.0f} at "
                f"M={m_lo} (gate: <=4x)")
    # chunked-memory scale gate: across the large-model cells at one shared
    # chunk_size, peak per-stage device delta bytes must be flat in N —
    # sublinear growth between the two model sizes AND under an absolute
    # ceiling set by the chunk width alone (candidate-only, no baseline
    # cell needed)
    by_chunk = {}
    for key, c in candidate.items():
        if key[7] != "cnn" and c.get("chunk_size") \
                and c.get("peak_delta_device_bytes") \
                and c.get("n_params"):
            by_chunk.setdefault(c["chunk_size"], []).append(c)
    for csize, cells in sorted(by_chunk.items()):
        for c in cells:
            ceiling = 24 * c["participants_per_round"] * csize
            rows.append(f"  {c['model']:16s} N={c['n_params']:,} peak delta "
                        f"{c['peak_delta_device_bytes']/1e6:.2f} MB "
                        f"({c['num_chunks']} chunks)")
            if c["peak_delta_device_bytes"] > ceiling:
                failures.append(
                    f"{c['model']}: peak delta device bytes "
                    f"{c['peak_delta_device_bytes']} exceed the chunk-width "
                    f"ceiling {ceiling} (24 * K * chunk_size) — a flat "
                    f"(K, N) stage is back in a round body")
        cells = sorted(cells, key=lambda c: c["n_params"])
        lo, hi = cells[0], cells[-1]
        n_ratio = hi["n_params"] / lo["n_params"]
        if hi is not lo and n_ratio >= 2:
            p_ratio = hi["peak_delta_device_bytes"] / \
                max(lo["peak_delta_device_bytes"], 1)
            rows.append(f"  {'chunked peak':16s} x{p_ratio:.2f} while N "
                        f"grew x{n_ratio:.2f} (chunk_size {csize:,})")
            if p_ratio > 0.5 * n_ratio:
                failures.append(
                    f"chunked peak delta memory is not flat in N: "
                    f"x{p_ratio:.2f} growth against x{n_ratio:.2f} params "
                    f"at chunk_size {csize} (gate: <= half the N growth)")
    if speeds:
        geomean = math.exp(sum(math.log(s) for s in speeds) / len(speeds))
        rows.append(f"  {'geomean':16s} rounds/s x{geomean:5.2f}")
        if geomean < 1.0 - max_slowdown:
            failures.append(
                f"aggregate: geomean throughput x{geomean:.2f} is more than "
                f"{max_slowdown:.0%} below baseline")
    return failures, skipped, rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_fleet.json")
    ap.add_argument("--candidate", default="BENCH_fleet_smoke.json")
    ap.add_argument("--max-slowdown", type=float, default=0.30,
                    help="fail when geomean rounds/sec drops by more than "
                         "this fraction, or any cell by twice it "
                         "(default 0.30)")
    ap.add_argument("--bytes-tol", type=float, default=0.02,
                    help="fail when bytes-on-wire/round grow by more than "
                         "this fraction (default 0.02)")
    ap.add_argument("--quorum-tol", type=float, default=0.05,
                    help="fail when a fault cell's mean quorum fraction "
                         "drops by more than this (absolute, default 0.05)")
    args = ap.parse_args()

    try:
        baseline = _cells(args.baseline)
        candidate = _cells(args.candidate)
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"[check_regression] cannot load inputs: {e}", file=sys.stderr)
        return 2
    if not candidate:
        print("[check_regression] candidate run has no cells",
              file=sys.stderr)
        return 2

    failures, skipped, rows = compare(
        baseline, candidate, max_slowdown=args.max_slowdown,
        bytes_tol=args.bytes_tol, quorum_tol=args.quorum_tol)
    print(f"[check_regression] {args.candidate} vs {args.baseline}")
    for row in rows:
        print(row)
    for name in skipped:
        print(f"  {name:16s} (no baseline cell — skipped)")
    if not rows:
        print("[check_regression] no overlapping cells to compare",
              file=sys.stderr)
        return 2
    if failures:
        print("\nPERF REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("perf gate green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
