"""Paper Table XII: FedS3A vs FedAvg-SSL-Partial / FedAvg-SSL-All /
FedAsync-SSL / Local-SSL (performance + ART + ACO)."""
import time

from benchmarks.common import csv_row, dataset, fmt_row, run_feds3a
from repro.core import FedAvgSSL, FedAsyncSSL, FedS3AConfig, LocalSSL


def _run_baseline(cls, scenario, bench_mode, **kw):
    data = dataset(scenario, bench_mode["scale"], 0.05, 0)
    cfg = FedS3AConfig(rounds=bench_mode["rounds"])
    t0 = time.time()
    algo = cls(data, cfg, **kw)
    res = algo.train()
    res["wall_s"] = time.time() - t0
    return res


def run(mode, out):
    for scenario in mode["scenarios"]:
        res = run_feds3a(scenario, scale=mode["scale"], rounds=mode["rounds"])
        print(fmt_row(f"[T12 {scenario}] FedS3A", res))
        out.append(csv_row("T12", scenario, "FedS3A", res))

        for name, cls, kw in (
            ("FedAvg-SSL-Partial", FedAvgSSL, dict(mode="partial")),
            ("FedAvg-SSL-All", FedAvgSSL, dict(mode="all")),
        ):
            res = _run_baseline(cls, scenario, mode, **kw)
            print(fmt_row(f"[T12 {scenario}] {name}", res))
            out.append(csv_row("T12", scenario, name, res))

        # FedAsync aggregates per-arrival: give it M x rounds arrivals for a
        # comparable wall-clock horizon
        amode = dict(mode, rounds=mode["rounds"] * 4)
        res = _run_baseline(FedAsyncSSL, scenario, amode)
        print(fmt_row(f"[T12 {scenario}] FedAsync-SSL", res))
        out.append(csv_row("T12", scenario, "FedAsync-SSL", res))

    res = _run_baseline(LocalSSL, "balanced", mode)
    print(fmt_row("[T12 balanced] Local-SSL", res))
    out.append(csv_row("T12", "balanced", "Local-SSL", res))
