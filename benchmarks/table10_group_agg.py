"""Paper Table X: group-based aggregation ablation (basic scenario only —
in the balanced scenario grouping degenerates to random groups, §V-E1)."""
from benchmarks.common import csv_row, fmt_row, run_feds3a


def run(mode, out):
    for gb, name in ((False, "non_group"), (True, "group_based")):
        res = run_feds3a("basic", scale=mode["scale"], rounds=mode["rounds"],
                         group_based=gb)
        print(fmt_row(f"[T10 basic] {name}", res))
        out.append(csv_row("T10", "basic", name, res))
