"""Paper Table V: impact of the staleness function g(r - r_i)."""
from benchmarks.common import csv_row, fmt_row, run_feds3a

VARIANTS = ["constant", "polynomial", "hinge", "exponential"]


def run(mode, out):
    for scenario in mode["scenarios"]:
        for fn in VARIANTS:
            res = run_feds3a(scenario, scale=mode["scale"],
                             rounds=mode["rounds"], staleness_function=fn)
            print(fmt_row(f"[T5 {scenario}] {fn}", res))
            out.append(csv_row("T5", scenario, fn, res))
