"""Fleet-scale benchmark: the sharded round engine vs fleet size and devices.

Measures steady-state rounds/sec and TRUE bytes-on-wire of the sharded fleet
engine over K ∈ {8, 64, 512, 2048} clients and a sweep of device counts.
The device count is baked into the XLA client at process start
(``--xla_force_host_platform_device_count``), so the driver re-launches
itself as one worker subprocess per cell and aggregates their reports into
BENCH_fleet.json.

Per (K, D) cell: a ``make_fleet_dataset`` federation (Table III rows tiled
cyclically with per-client size jitter), the reduced-width bench CNN, one
warm-up round absorbing XLA compilation, then ``--rounds`` timed rounds.
Bytes-on-wire comes from the SparseComm deferred counters; under the
(default) CSR wire format this is the actual compacted payload size —
values + indices + row_ptr of arrays that really exist — broken down per
component in the report. For each K an extra error-feedback cell at the
highest device count reports the sparse residual store footprint against
the dense (M, N) equivalent it replaced, and an extra ``base_store="dense"``
cell pins the versioned base store's two wins: server base memory
(O(tau*N + M) ring + chain vs the O(M*N) base matrix, reported as
``base_store_bytes``) and distribution bytes-on-wire (chain-delta broadcast
— each transition payload once a round, at most tau+1 — vs one encode per
target;
the versioned cells also report the broadcast-only ledger as
``dist_payload_bytes_per_round``). A ``--faults`` cell per K runs the
REFERENCE_CHURN traffic model (crash 10%, upload loss 5%, churn) with a
round deadline and quorum floor, reporting fleet-health aggregates
(``degraded_rounds``, ``mean_quorum_frac``, ``resyncs``, ``crashes``,
``lost_uploads``) so the regression gate can bound round-efficiency
degradation. A final ``wire_format="csr_q"`` cell per K (with EF, so the
dequantization error is re-offered) measures the int8-quantized wire
format against its f32 CSR twin at the same (K, D): the gate pins its
payload at <=0.4x the twin's, rounds/sec at >=0.9x, and final accuracy
within 1e-2.

  PYTHONPATH=src python -m benchmarks.bench_fleet            # full sweep
  PYTHONPATH=src python -m benchmarks.bench_fleet --smoke    # CI: K<=64,
                                                             # D in {1,4}

Smoke mode times the SAME number of rounds as the full sweep (only the
K/D grid shrinks) so its cells are directly comparable to the committed
baseline — a shorter timed window would misattribute one-off retraces to
throughput and sample a different per-round byte average.

``benchmarks/check_regression.py`` diffs a smoke run against the committed
BENCH_fleet.json and fails CI on throughput/bytes regressions.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

FULL_CLIENTS = (8, 64, 512, 2048)
SMOKE_CLIENTS = (8, 64)
FULL_DEVICES = (1, 2, 4)
SMOKE_DEVICES = (1, 4)


def bench_cell(num_clients, *, rounds, seed=0, error_feedback=False,
               base_store="versioned", faults=False, wire_format="csr"):
    """One (K, current-device-count) measurement. Import jax lazily so the
    driver process never initializes an XLA client."""
    import jax

    from repro.configs.feds3a_cnn import CNNConfig
    from repro.core import REFERENCE_CHURN, FedS3AConfig, FedS3ATrainer
    from repro.core.metrics import fleet_health
    from repro.data import make_fleet_dataset

    warmup = 3                             # distinct distribution-target
    cnn = CNNConfig(name="feds3a-cnn-fleet", conv_filters=(8, 8), hidden=16)
    data = make_fleet_dataset(num_clients, scale=0.0008, seed=seed)
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=rounds + warmup, seed=seed, engine="sharded", cnn=cnn,
        C=0.5, batch_size=50, error_feedback=error_feedback,
        base_store=base_store, wire_format=wire_format,
        # fault cell: the reference churn profile with a round deadline, so
        # the report carries a round-efficiency number (mean_quorum_frac)
        # the regression gate can bound
        traffic=REFERENCE_CHURN if faults else None,
        round_deadline=700.0 if faults else None,
        quorum_floor=2 if faults else 1))

    for _ in range(warmup):                # shapes retrace the first rounds
        tr.run_round()
    jax.block_until_ready(tr._global_flat)
    payload0, dense0 = tr.comm.payload_bytes, tr.comm.dense_bytes
    wire0 = tr.comm.wire_breakdown()
    dist0 = tr.store.dist_payload_bytes() if base_store == "versioned" else 0

    t0 = time.perf_counter()
    for _ in range(rounds):
        tr.run_round()
    jax.block_until_ready(tr._global_flat)
    elapsed = time.perf_counter() - t0
    wire1 = tr.comm.wire_breakdown()
    dist1 = tr.store.dist_payload_bytes() if base_store == "versioned" else 0

    n_params = int(tr._global_flat.shape[0])
    fleet = fleet_health(tr.logs)
    return {
        "clients": num_clients,
        "devices": len(jax.devices()),
        "error_feedback": error_feedback,
        "base_store": base_store,
        "faults": faults,
        "wire_format": wire_format,
        # fleet-health aggregates over the whole run (warmup + timed):
        # deterministic for a fixed seed, so the gate can pin them
        "degraded_rounds": fleet["degraded_rounds"],
        "mean_quorum_frac": fleet["mean_quorum_frac"],
        "resyncs": fleet["resyncs"],
        "crashes": fleet["crashes"],
        "lost_uploads": fleet["lost_uploads"],
        # server-side base-model state: the versioned ring + chain is
        # O(tau*N + M); the dense equivalent is the (M, N) matrix
        "base_store_bytes": tr.base_store_bytes(),
        "base_store_dense_equiv_bytes": len(data["clients"]) * n_params * 4,
        # broadcast-only distribution ledger (versioned store; 0 for dense
        # — there distribution bytes are folded into payload_bytes only)
        "dist_payload_bytes_per_round": (dist1 - dist0) / rounds,
        "participants_per_round": tr.scheduler.k,
        "rounds_timed": rounds,
        "s_per_round": elapsed / rounds,
        "rounds_per_sec": rounds / elapsed,
        "payload_bytes_per_round": (tr.comm.payload_bytes - payload0) / rounds,
        "dense_bytes_per_round": (tr.comm.dense_bytes - dense0) / rounds,
        # CSR component breakdown of the bytes actually put on the wire
        "wire_values_bytes_per_round":
            (wire1["values_bytes"] - wire0["values_bytes"]) / rounds,
        "wire_indices_bytes_per_round":
            (wire1["indices_bytes"] - wire0["indices_bytes"]) / rounds,
        "wire_row_ptr_bytes_per_round":
            (wire1["row_ptr_bytes"] - wire0["row_ptr_bytes"]) / rounds,
        "wire_scales_bytes_per_round":
            (wire1["scales_bytes"] - wire0["scales_bytes"]) / rounds,
        "aco": tr.comm.aco,
        # per-client EF residual state: sparse CSR store vs the dense (M, N)
        # matrix it replaced (0 when EF is off)
        "residual_store_bytes": tr.residual_store_bytes(),
        "residual_dense_equiv_bytes":
            len(data["clients"]) * n_params * 4 if error_feedback else 0,
        "final_accuracy": float(tr.evaluate()["accuracy"]),
    }


def worker(args):
    results = [bench_cell(k, rounds=args.rounds, seed=args.seed,
                          error_feedback=args.ef, base_store=args.base_store,
                          faults=args.faults, wire_format=args.wire_format)
               for k in args.clients]
    with open(args.out, "w") as f:
        json.dump(results, f)


def _cells(args):
    """(devices, clients, error_feedback, base_store, faults, wire_format)
    cells: the plain sweep (versioned store, f32 CSR, the defaults) plus —
    at the highest device count — one EF cell per K (the residual-store
    story), one dense-base-store cell per K (the versioned-store memory +
    distribution-bytes story), one fault-injected cell per K
    (REFERENCE_CHURN + round deadline: the graceful-degradation story,
    gated on round efficiency), and one quantized-wire (csr_q + EF) cell
    per K (the int8 payload story, gated against its f32 CSR twin)."""
    dmax = max(args.devices)
    cells = [(d, k, False, "versioned", False, "csr") for d in args.devices
             for k in args.clients]
    cells += [(dmax, k, True, "versioned", False, "csr")
              for k in args.clients]
    cells += [(dmax, k, False, "dense", False, "csr") for k in args.clients]
    cells += [(dmax, k, False, "versioned", True, "csr")
              for k in args.clients]
    # csr_q rides with EF so the dequantization error is re-offered instead
    # of dropped — the configuration the accuracy gate compares to its EF
    # f32 twin
    cells += [(dmax, k, True, "versioned", False, "csr_q")
              for k in args.clients]
    return cells


def driver(args):
    # one subprocess per cell: the device count is frozen at XLA client
    # init, and sharing a process between cells contaminates the timings
    # (measured 4-5x on the later cell — lingering executables and
    # allocator state), so every cell gets a pristine runtime
    results = []
    for d, k, ef, store, faults, wire in _cells(args):
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if "--xla_force_host_platform_device_count" not in f]
        env["XLA_FLAGS"] = " ".join(
            flags + [f"--xla_force_host_platform_device_count={d}"])
        out = f".bench_fleet_worker_{d}_{k}_{int(ef)}_{store}_{int(faults)}" \
              f"_{wire}.json"
        cmd = [sys.executable, "-m", "benchmarks.bench_fleet",
               "--worker", "--out", out, "--rounds", str(args.rounds),
               "--seed", str(args.seed), "--clients", str(k),
               "--base-store", store, "--wire-format", wire]
        if ef:
            cmd.append("--ef")
        if faults:
            cmd.append("--faults")
        print(f"[bench_fleet] K={k} devices={d} ef={ef} store={store} "
              f"faults={faults} wire={wire}", flush=True)
        subprocess.run(cmd, env=env, check=True)
        with open(out) as f:
            results.extend(json.load(f))
        os.remove(out)

    for r in results:
        tag = " q8" if r.get("wire_format", "csr") == "csr_q" else \
            (" ef" if r["error_feedback"] else
             (" fx" if r.get("faults") else
              (" db" if r.get("base_store") == "dense" else "")))
        print(f"  K={r['clients']:5d} D={r['devices']}{tag:3s} "
              f"{r['rounds_per_sec']:7.3f} rounds/s "
              f"({r['s_per_round']*1e3:8.1f} ms/round)  "
              f"wire {r['payload_bytes_per_round']/1e6:8.2f} MB/round "
              f"(aco {r['aco']:.3f})  "
              f"base store {r['base_store_bytes']/1e6:.2f} MB")
        if r["error_feedback"]:
            print(f"        residual store {r['residual_store_bytes']/1e6:.2f}"
                  f" MB vs {r['residual_dense_equiv_bytes']/1e6:.2f} MB dense")
        if r.get("faults"):
            print(f"        quorum {r['mean_quorum_frac']:.3f} "
                  f"degraded {r['degraded_rounds']} "
                  f"crashes {r['crashes']} lost {r['lost_uploads']} "
                  f"resyncs {r['resyncs']}")
    # scaling summary: rounds/sec at each K, normalized to the 1-device run
    summary = {}
    for r in results:
        if not r["error_feedback"] and r.get("base_store") != "dense" \
                and not r.get("faults") \
                and r.get("wire_format", "csr") == "csr":
            summary.setdefault(r["clients"], {})[r["devices"]] = \
                r["rounds_per_sec"]
    scaling = {
        str(k): {str(d): v / by_d[min(by_d)] for d, v in sorted(by_d.items())}
        for k, by_d in summary.items()}
    with open(args.json, "w") as f:
        json.dump({"results": results, "speedup_vs_min_devices": scaling},
                  f, indent=2)
    print(f"JSON -> {args.json}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: K<=64, devices {1,4}")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=lambda s: tuple(
        int(x) for x in s.split(",")), default=None)
    ap.add_argument("--devices", type=lambda s: tuple(
        int(x) for x in s.split(",")), default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_fleet.json")
    ap.add_argument("--base-store", default="versioned",
                    choices=("versioned", "dense"), help=argparse.SUPPRESS)
    ap.add_argument("--ef", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--faults", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--wire-format", dest="wire_format", default="csr",
                    choices=("csr", "csr_q", "dense_masked"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.clients is None:
        args.clients = SMOKE_CLIENTS if args.smoke else FULL_CLIENTS
    if args.devices is None:
        args.devices = SMOKE_DEVICES if args.smoke else FULL_DEVICES
    if args.rounds is None:
        args.rounds = 5

    if args.worker:
        worker(args)
    else:
        driver(args)


if __name__ == "__main__":
    main()
