"""Fleet-scale benchmark: the sharded round engine vs fleet size and devices.

Measures steady-state rounds/sec and TRUE bytes-on-wire of the sharded fleet
engine over K ∈ {8, 64, 512, 2048} clients and a sweep of device counts.
The device count is baked into the XLA client at process start
(``--xla_force_host_platform_device_count``), so the driver re-launches
itself as one worker subprocess per cell and aggregates their reports into
BENCH_fleet.json.

Per (K, D) cell: a ``make_fleet_dataset`` federation (Table III rows tiled
cyclically with per-client size jitter), the reduced-width bench CNN, one
warm-up round absorbing XLA compilation, then ``--rounds`` timed rounds.
Bytes-on-wire comes from the SparseComm deferred counters; under the
(default) CSR wire format this is the actual compacted payload size —
values + indices + row_ptr of arrays that really exist — broken down per
component in the report. For each K an extra error-feedback cell at the
highest device count reports the sparse residual store footprint against
the dense (M, N) equivalent it replaced, and an extra ``base_store="dense"``
cell pins the versioned base store's two wins: server base memory
(O(tau*N + M) ring + chain vs the O(M*N) base matrix, reported as
``base_store_bytes``) and distribution bytes-on-wire (chain-delta broadcast
— each transition payload once a round, at most tau+1 — vs one encode per
target;
the versioned cells also report the broadcast-only ledger as
``dist_payload_bytes_per_round``). A ``--faults`` cell per K runs the
REFERENCE_CHURN traffic model (crash 10%, upload loss 5%, churn) with a
round deadline and quorum floor, reporting fleet-health aggregates
(``degraded_rounds``, ``mean_quorum_frac``, ``resyncs``, ``crashes``,
``lost_uploads``) so the regression gate can bound round-efficiency
degradation. A final ``wire_format="csr_q"`` cell per K (with EF, so the
dequantization error is re-offered) measures the int8-quantized wire
format against its f32 CSR twin at the same (K, D): the gate pins its
payload at <=0.4x the twin's, rounds/sec at >=0.9x, and final accuracy
within 1e-2. A ``client_store="paged"`` (EF) cell per K measures the
host-paged per-client state layout against its resident EF twin — every
cell reports ``client_state_device_bytes`` / ``client_state_host_bytes`` /
``client_state_resident_equiv_bytes``, and the scale gate requires paged
device bytes strictly below the resident equivalent, rounds/sec >= 0.9x
the resident twin at K <= 2048, and per-participant device bytes FLAT in M
across the paged cells. The flat-in-M claim is anchored by the
M=1,000,000 scale cell (``SCALE_CELL``): a paged round over a million
clients (64 pooled dataset shards, 512 participants/round, one device)
that runs in both the full and smoke sweeps. A ``--checkpoint`` (EF) cell
per K measures crash-consistent fleet checkpointing
(``checkpoint_every=5``: atomic tmp+rename section writes, sha256
manifest commit, rolling retention) against a same-process no-checkpoint
twin, reporting snapshot bytes and per-save wall time — the gate pins
checkpointing throughput at >=0.95x the twin's.

Two large-model cells (``LM_CELLS``) run a REAL reduced transformer from
the config zoo through the chunked parameter axis
(``FedS3AConfig(model=..., chunk_size=...)``): two model sizes (~0.2M and
~1.3M params) at the SAME chunk_size, each reporting
``peak_delta_device_bytes`` — the trainer's bound on per-stage (K, chunk)
delta buffers. The regression gate pins that bound FLAT IN N: the bigger
model's peak must grow far slower than its parameter count (and stay under
an absolute ceiling set by chunk_size alone), which is the chunked
streaming claim. The flat CNN cells are untouched — their cell keys and
gates are unchanged.

  PYTHONPATH=src python -m benchmarks.bench_fleet            # full sweep
  PYTHONPATH=src python -m benchmarks.bench_fleet --smoke    # CI: K<=64,
                                                             # D in {1,4}

Smoke mode times the SAME number of rounds as the full sweep (only the
K/D grid shrinks) so its cells are directly comparable to the committed
baseline — a shorter timed window would misattribute one-off retraces to
throughput and sample a different per-round byte average.

``benchmarks/check_regression.py`` diffs a smoke run against the committed
BENCH_fleet.json and fails CI on throughput/bytes regressions.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

FULL_CLIENTS = (8, 64, 512, 2048)
SMOKE_CLIENTS = (8, 64)
FULL_DEVICES = (1, 2, 4)
SMOKE_DEVICES = (1, 4)

# chunked large-model cells: two reduced-transformer sizes at ONE shared
# chunk_size, so the gate can require peak delta memory flat in N. The
# small preset trims the reduced qwen2-1.5b to ~0.2M params; the large one
# is the full reduced config (~1.3M). Both stream over ~2-10 leaf-aligned
# chunks — modest on purpose: the chunk loop unrolls inside the jits, so
# chunk count is compile time.
LM_PRESETS = {
    "lm-small": dict(num_layers=1, d_model=128, d_ff=256, num_heads=2,
                     num_kv_heads=1),
    "lm-large": {},
}
LM_CHUNK_SIZE = 131072
LM_CELLS = [{"model": m, "clients": 8, "rounds": 3, "warmup": 1}
            for m in ("lm-small", "lm-large")]


def _lm_config(preset):
    from repro.configs import get_config, load_all
    load_all()
    return get_config("qwen2-1.5b").reduced(**LM_PRESETS[preset])


CKPT_EVERY = 5


def bench_cell(num_clients, *, rounds, seed=0, error_feedback=False,
               base_store="versioned", faults=False, wire_format="csr",
               client_store="resident", pool=None, participants=None,
               warmup=None, model=None, chunk_size=0, checkpoint=False):
    """One (K, current-device-count) measurement. Import jax lazily so the
    driver process never initializes an XLA client.

    ``client_store="paged"`` benches the host-paged per-client state layout;
    ``pool`` / ``participants`` / ``warmup`` parameterize the million-client
    scale cell (pooled dataset shards, absolute participation count, shorter
    warmup — the scheduler's mass tau-forcing wave is the expensive part,
    and one warmup round is enough to absorb compilation)."""
    import jax

    from repro.configs.feds3a_cnn import CNNConfig
    from repro.core import REFERENCE_CHURN, FedS3AConfig, FedS3ATrainer
    from repro.core.metrics import fleet_health
    from repro.data import make_fleet_dataset, make_lm_dataset

    warmup = 3 if warmup is None else warmup   # distinct distribution-target
    # paged cells carry the 0.9x throughput gate, and a tiny fleet's round
    # is tens of milliseconds — a fixed count would time mere milliseconds
    # of work and the ratio would flap on scheduler noise, so scale their
    # timed rounds to a comparable work window. Resident cells keep the
    # short count: their absolute numbers are gated with 30%+ tolerance,
    # and long multi-device runs needlessly multiply exposure to XLA:CPU's
    # rare collective-rendezvous stalls on oversubscribed hosts. The scale
    # cell passes ``participants`` and keeps its short explicit count.
    # checkpoint cells carry the 0.95x overhead gate and need the same
    # treatment (plus enough timed rounds to span several save cadences)
    if participants is None and (client_store == "paged" or checkpoint):
        rounds = rounds * max(1, 1024 // num_clients)
    cnn = CNNConfig(name="feds3a-cnn-fleet", conv_filters=(8, 8), hidden=16)
    C = 0.5 if participants is None else participants / num_clients
    ckpt_root = tempfile.mkdtemp(prefix="bench_fleet_ckpt_") \
        if checkpoint else None

    def build(store, ckpt=False):
        # each trainer gets its own dataset object: identical content (same
        # seed), no shared mutable client dicts between twin runs
        if model is not None:
            # chunked large-model cell: a real reduced transformer as a
            # final-token classifier over the synthetic token federation
            mcfg = _lm_config(model)
            return FedS3ATrainer(
                make_lm_dataset(num_clients, vocab_size=mcfg.vocab_size,
                                seq_len=12, samples_per_client=24,
                                seed=seed),
                FedS3AConfig(
                    rounds=rounds + warmup, seed=seed, model=mcfg,
                    chunk_size=chunk_size, C=C, batch_size=16,
                    error_feedback=error_feedback, base_store=base_store,
                    wire_format=wire_format, client_store=store,
                    checkpoint_dir=ckpt_root if ckpt else None,
                    checkpoint_every=CKPT_EVERY if ckpt else 0))
        return FedS3ATrainer(
            make_fleet_dataset(num_clients, scale=0.0008, seed=seed,
                               pool=pool),
            FedS3AConfig(
                rounds=rounds + warmup, seed=seed, engine="sharded", cnn=cnn,
                C=C, batch_size=50, error_feedback=error_feedback,
                base_store=base_store, wire_format=wire_format,
                client_store=store,
                checkpoint_dir=ckpt_root if ckpt else None,
                checkpoint_every=CKPT_EVERY if ckpt else 0,
                # fault cell: the reference churn profile with a round
                # deadline, so the report carries a round-efficiency number
                # (mean_quorum_frac) the regression gate can bound
                traffic=REFERENCE_CHURN if faults else None,
                round_deadline=700.0 if faults else None,
                quorum_floor=2 if faults else 1))

    tr = build(client_store, ckpt=checkpoint)
    data = tr.data
    # the paged-vs-resident throughput gate needs a ratio immune to
    # between-process variance (CPU frequency / allocator state swing
    # separate worker invocations by far more than the 10% budget), so the
    # paged cell times its RESIDENT twin in the same process, interleaved
    # block-wise below. The million-client scale cell skips the twin — its
    # resident layout would need the very device footprint paging removes.
    # Checkpoint cells interleave a NO-checkpoint twin the same way: the
    # 0.95x save-overhead gate is a same-process ratio too.
    if client_store == "paged" and participants is None:
        twin = build("resident")
    elif checkpoint:
        twin = build(client_store, ckpt=False)
    else:
        twin = None

    # one round, plus the checkpoint-cadence save when the trainer carries a
    # checkpoint_dir (the twin never does, so _step is a plain round there).
    # wait=False is the same background-writer path train() uses; the
    # timed window still pays the full cost because every timed block ends
    # with a drain, so trailing writer work cannot leak past the clock.
    # checkpoint_save_s_mean therefore reports the synchronous snapshot
    # cost the training loop is actually exposed to per save.
    ckpt_saves = [0, 0.0]

    def _step(t):
        t.run_round()
        c = t.cfg
        if c.checkpoint_dir and c.checkpoint_every \
                and t.global_version % c.checkpoint_every == 0:
            s0 = time.perf_counter()
            t.save_checkpoint(wait=False)
            ckpt_saves[0] += 1
            ckpt_saves[1] += time.perf_counter() - s0

    for _ in range(warmup):                # shapes retrace the first rounds
        _step(tr)
    if checkpoint:
        # one untimed save: the first snapshot pays one-off host-transfer
        # warmup the same way the first round pays compilation
        tr.save_checkpoint()
        ckpt_saves[:] = [0, 0.0]
    jax.block_until_ready(tr._global_flat)
    payload0, dense0 = tr.comm.payload_bytes, tr.comm.dense_bytes
    wire0 = tr.comm.wire_breakdown()
    dist0 = tr.store.dist_payload_bytes() if base_store == "versioned" else 0

    if twin is None:
        t0 = time.perf_counter()
        for _ in range(rounds):
            _step(tr)
        if checkpoint:
            tr._ckpt_drain()
        jax.block_until_ready(tr._global_flat)
        elapsed = time.perf_counter() - t0
        twin_elapsed = None
    else:
        for _ in range(warmup):
            _step(twin)
        jax.block_until_ready(twin._global_flat)
        per = max(1, rounds // 4)          # A/B/A/B interleaved blocks
        elapsed = twin_elapsed = 0.0
        done = 0
        while done < rounds:
            nb = min(per, rounds - done)
            t0 = time.perf_counter()
            for _ in range(nb):
                _step(tr)
            if checkpoint:
                tr._ckpt_drain()
            jax.block_until_ready(tr._global_flat)
            elapsed += time.perf_counter() - t0
            t0 = time.perf_counter()
            for _ in range(nb):
                _step(twin)
            jax.block_until_ready(twin._global_flat)
            twin_elapsed += time.perf_counter() - t0
            done += nb
    wire1 = tr.comm.wire_breakdown()
    dist1 = tr.store.dist_payload_bytes() if base_store == "versioned" else 0

    # checkpoint footprint: the on-disk size of one complete (newest)
    # snapshot — every section file plus its MANIFEST
    ckpt_bytes = 0
    if checkpoint:
        from repro.core import fleet_ckpt
        path, _ = fleet_ckpt.find_restorable(ckpt_root)
        if path is not None:
            ckpt_bytes = sum(os.path.getsize(os.path.join(path, f))
                             for f in os.listdir(path))
        shutil.rmtree(ckpt_root, ignore_errors=True)

    n_params = int(tr._global_flat.shape[0])
    fleet = fleet_health(tr.logs)
    return {
        "clients": num_clients,
        "devices": len(jax.devices()),
        "error_feedback": error_feedback,
        "base_store": base_store,
        "faults": faults,
        "wire_format": wire_format,
        "client_store": client_store,
        # chunked parameter axis: the model driven through the round, the
        # resolved layout, and the trainer's peak per-stage device delta
        # bound — what the flat-in-N gate pins across the LM cells
        "model": model or "cnn",
        "n_params": n_params,
        "chunk_size": chunk_size,
        "num_chunks": tr.layout.num_chunks if tr.chunked else 1,
        "peak_delta_device_bytes": tr.peak_delta_device_bytes(),
        # per-client state split by residence: the paged store keeps a
        # device window of O(K * page) bytes — flat in M — while the
        # resident layout's device share IS the resident-equivalent
        "client_state_device_bytes": tr.client_state_device_bytes(),
        "client_state_host_bytes": tr.client_state_host_bytes(),
        "client_state_resident_equiv_bytes":
            tr.client_state_resident_equiv_bytes(),
        # fleet-health aggregates over the whole run (warmup + timed):
        # deterministic for a fixed seed, so the gate can pin them
        "degraded_rounds": fleet["degraded_rounds"],
        "mean_quorum_frac": fleet["mean_quorum_frac"],
        "resyncs": fleet["resyncs"],
        "crashes": fleet["crashes"],
        "lost_uploads": fleet["lost_uploads"],
        # server-side base-model state: the versioned ring + chain is
        # O(tau*N + M); the dense equivalent is the (M, N) matrix
        "base_store_bytes": tr.base_store_bytes(),
        "base_store_dense_equiv_bytes": len(data["clients"]) * n_params * 4,
        # broadcast-only distribution ledger (versioned store; 0 for dense
        # — there distribution bytes are folded into payload_bytes only)
        "dist_payload_bytes_per_round": (dist1 - dist0) / rounds,
        "participants_per_round": tr.scheduler.k,
        "rounds_timed": rounds,
        "s_per_round": elapsed / rounds,
        "rounds_per_sec": rounds / elapsed,
        # same-process interleaved resident-twin throughput (paged cells
        # only): the denominator of the regression gate's 0.9x ratio
        "resident_twin_rounds_per_sec":
            (rounds / twin_elapsed)
            if twin_elapsed and client_store == "paged" else None,
        # crash-consistent checkpointing cell: snapshot size, per-save wall
        # time, and the same-process no-checkpoint twin throughput the
        # 0.95x overhead gate divides by
        "checkpoint": checkpoint,
        "checkpoint_every": CKPT_EVERY if checkpoint else 0,
        "checkpoint_bytes": ckpt_bytes,
        "checkpoint_saves": ckpt_saves[0],
        "checkpoint_save_s_mean":
            (ckpt_saves[1] / ckpt_saves[0]) if ckpt_saves[0] else 0.0,
        "no_ckpt_twin_rounds_per_sec":
            (rounds / twin_elapsed)
            if twin_elapsed and checkpoint else None,
        "payload_bytes_per_round": (tr.comm.payload_bytes - payload0) / rounds,
        "dense_bytes_per_round": (tr.comm.dense_bytes - dense0) / rounds,
        # CSR component breakdown of the bytes actually put on the wire
        "wire_values_bytes_per_round":
            (wire1["values_bytes"] - wire0["values_bytes"]) / rounds,
        "wire_indices_bytes_per_round":
            (wire1["indices_bytes"] - wire0["indices_bytes"]) / rounds,
        "wire_row_ptr_bytes_per_round":
            (wire1["row_ptr_bytes"] - wire0["row_ptr_bytes"]) / rounds,
        "wire_scales_bytes_per_round":
            (wire1["scales_bytes"] - wire0["scales_bytes"]) / rounds,
        "aco": tr.comm.aco,
        # per-client EF residual state: sparse CSR store vs the dense (M, N)
        # matrix it replaced (0 when EF is off)
        "residual_store_bytes": tr.residual_store_bytes(),
        "residual_dense_equiv_bytes":
            len(data["clients"]) * n_params * 4 if error_feedback else 0,
        "final_accuracy": float(tr.evaluate()["accuracy"]),
    }


def worker(args):
    results = [bench_cell(k, rounds=args.rounds, seed=args.seed,
                          error_feedback=args.ef, base_store=args.base_store,
                          faults=args.faults, wire_format=args.wire_format,
                          client_store=args.client_store, pool=args.pool,
                          participants=args.participants, warmup=args.warmup,
                          model=args.model, chunk_size=args.chunk_size,
                          checkpoint=args.checkpoint)
               for k in args.clients]
    with open(args.out, "w") as f:
        json.dump(results, f)


# the million-client scale cell: paged client store over a 64-shard pooled
# dataset, 512 participants per round, one device — the headline run whose
# device-resident client-state bytes the scale gate pins flat in M. One
# warmup round (compilation); the per-round cost at this M is dominated by
# the scheduler's mass tau-forcing wave, which the timed rounds include.
SCALE_CELL = {"clients": 1_000_000, "devices": 1, "pool": 64,
              "participants": 512, "rounds": 3, "warmup": 1}


def _cells(args):
    """(devices, clients, error_feedback, base_store, faults, wire_format,
    client_store) cells: the plain sweep (versioned store, f32 CSR, the
    defaults) plus — at the highest device count — one EF cell per K (the
    residual-store story), one dense-base-store cell per K (the
    versioned-store memory + distribution-bytes story), one fault-injected
    cell per K (REFERENCE_CHURN + round deadline: the graceful-degradation
    story, gated on round efficiency), one quantized-wire (csr_q + EF) cell
    per K (the int8 payload story, gated against its f32 CSR twin), and one
    paged-client-store (EF) cell per K (the flat-device-memory story, gated
    against its resident twin on throughput and against the resident
    equivalent on bytes)."""
    dmax = max(args.devices)
    cells = [(d, k, False, "versioned", False, "csr", "resident", False)
             for d in args.devices for k in args.clients]
    cells += [(dmax, k, True, "versioned", False, "csr", "resident", False)
              for k in args.clients]
    cells += [(dmax, k, False, "dense", False, "csr", "resident", False)
              for k in args.clients]
    cells += [(dmax, k, False, "versioned", True, "csr", "resident", False)
              for k in args.clients]
    # csr_q rides with EF so the dequantization error is re-offered instead
    # of dropped — the configuration the accuracy gate compares to its EF
    # f32 twin
    cells += [(dmax, k, True, "versioned", False, "csr_q", "resident", False)
              for k in args.clients]
    # the paged twin rides with EF too: residual pages are the per-client
    # state whose device footprint the store removes, and its resident EF
    # twin above shares the same (K, D) for the throughput gate
    cells += [(dmax, k, True, "versioned", False, "csr", "paged", False)
              for k in args.clients]
    # crash-consistent checkpointing cell per K (EF, so the snapshot carries
    # the residual store too): reports snapshot bytes + per-save wall time,
    # and interleaves a no-checkpoint twin for the 0.95x overhead gate
    cells += [(dmax, k, True, "versioned", False, "csr", "resident", True)
              for k in args.clients]
    return cells


def driver(args):
    # one subprocess per cell: the device count is frozen at XLA client
    # init, and sharing a process between cells contaminates the timings
    # (measured 4-5x on the later cell — lingering executables and
    # allocator state), so every cell gets a pristine runtime
    results = []
    for d, k, ef, store, faults, wire, cstore, ckpt in _cells(args):
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if "--xla_force_host_platform_device_count" not in f]
        env["XLA_FLAGS"] = " ".join(
            flags + [f"--xla_force_host_platform_device_count={d}"])
        out = f".bench_fleet_worker_{d}_{k}_{int(ef)}_{store}_{int(faults)}" \
              f"_{wire}_{cstore}_{int(ckpt)}.json"
        cmd = [sys.executable, "-m", "benchmarks.bench_fleet",
               "--worker", "--out", out, "--rounds", str(args.rounds),
               "--seed", str(args.seed), "--clients", str(k),
               "--base-store", store, "--wire-format", wire,
               "--client-store", cstore]
        if ef:
            cmd.append("--ef")
        if faults:
            cmd.append("--faults")
        if ckpt:
            cmd.append("--checkpoint")
        print(f"[bench_fleet] K={k} devices={d} ef={ef} store={store} "
              f"faults={faults} wire={wire} cstore={cstore} ckpt={ckpt}",
              flush=True)
        subprocess.run(cmd, env=env, check=True)
        with open(out) as f:
            results.extend(json.load(f))
        os.remove(out)

    # the M=1,000,000 scale cell (both full and smoke sweeps — it IS the
    # headline claim, and the pooled dataset keeps it minutes, not hours)
    sc = SCALE_CELL
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "--xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count={sc['devices']}"])
    out = ".bench_fleet_worker_scale.json"
    print(f"[bench_fleet] K={sc['clients']} devices={sc['devices']} "
          f"paged scale cell (pool={sc['pool']}, "
          f"participants={sc['participants']})", flush=True)
    subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_fleet", "--worker",
         "--out", out, "--rounds", str(sc["rounds"]),
         "--seed", str(args.seed), "--clients", str(sc["clients"]),
         "--client-store", "paged", "--ef", "--pool", str(sc["pool"]),
         "--participants", str(sc["participants"]),
         "--warmup", str(sc["warmup"])],
        env=env, check=True)
    with open(out) as f:
        results.extend(json.load(f))
    os.remove(out)

    # the chunked large-model cells (both sweeps): two model sizes at one
    # shared chunk_size, one device each — the flat-in-N peak-memory claim
    for cell in LM_CELLS:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if "--xla_force_host_platform_device_count" not in f]
        env["XLA_FLAGS"] = " ".join(
            flags + ["--xla_force_host_platform_device_count=1"])
        out = f".bench_fleet_worker_{cell['model']}.json"
        print(f"[bench_fleet] {cell['model']} chunked cell "
              f"(chunk_size={LM_CHUNK_SIZE})", flush=True)
        subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_fleet", "--worker",
             "--out", out, "--rounds", str(cell["rounds"]),
             "--seed", str(args.seed), "--clients", str(cell["clients"]),
             "--model", cell["model"], "--chunk-size", str(LM_CHUNK_SIZE),
             "--warmup", str(cell["warmup"])],
            env=env, check=True)
        with open(out) as f:
            results.extend(json.load(f))
        os.remove(out)

    for r in results:
        tag = f" {r['model']}" if r.get("model", "cnn") != "cnn" else \
            " pg" if r.get("client_store", "resident") == "paged" else \
            (" ck" if r.get("checkpoint") else
             (" q8" if r.get("wire_format", "csr") == "csr_q" else
              (" ef" if r["error_feedback"] else
               (" fx" if r.get("faults") else
                (" db" if r.get("base_store") == "dense" else "")))))
        print(f"  K={r['clients']:5d} D={r['devices']}{tag:3s} "
              f"{r['rounds_per_sec']:7.3f} rounds/s "
              f"({r['s_per_round']*1e3:8.1f} ms/round)  "
              f"wire {r['payload_bytes_per_round']/1e6:8.2f} MB/round "
              f"(aco {r['aco']:.3f})  "
              f"base store {r['base_store_bytes']/1e6:.2f} MB")
        if r["error_feedback"]:
            print(f"        residual store {r['residual_store_bytes']/1e6:.2f}"
                  f" MB vs {r['residual_dense_equiv_bytes']/1e6:.2f} MB dense")
        if r.get("faults"):
            print(f"        quorum {r['mean_quorum_frac']:.3f} "
                  f"degraded {r['degraded_rounds']} "
                  f"crashes {r['crashes']} lost {r['lost_uploads']} "
                  f"resyncs {r['resyncs']}")
        if r.get("checkpoint"):
            print(f"        checkpoint: "
                  f"{r['checkpoint_bytes']/1e6:.2f} MB/snapshot, "
                  f"{r['checkpoint_save_s_mean']*1e3:.1f} ms/save "
                  f"(every {r['checkpoint_every']} rounds; twin "
                  f"{r['no_ckpt_twin_rounds_per_sec']:.3f} rounds/s)")
        if r.get("client_store", "resident") == "paged":
            print(f"        client state: device "
                  f"{r['client_state_device_bytes']/1e6:.2f} MB (window), "
                  f"host {r['client_state_host_bytes']/1e6:.2f} MB, "
                  f"resident equiv "
                  f"{r['client_state_resident_equiv_bytes']/1e6:.2f} MB")
        if r.get("model", "cnn") != "cnn":
            print(f"        {r['n_params']:,} params over "
                  f"{r['num_chunks']} chunks (chunk_size "
                  f"{r['chunk_size']:,}): peak delta "
                  f"{r['peak_delta_device_bytes']/1e6:.2f} MB on device")
    # scaling summary: rounds/sec at each K, normalized to the 1-device run
    summary = {}
    for r in results:
        if not r["error_feedback"] and r.get("base_store") != "dense" \
                and not r.get("faults") and r.get("model", "cnn") == "cnn" \
                and r.get("wire_format", "csr") == "csr":
            summary.setdefault(r["clients"], {})[r["devices"]] = \
                r["rounds_per_sec"]
    scaling = {
        str(k): {str(d): v / by_d[min(by_d)] for d, v in sorted(by_d.items())}
        for k, by_d in summary.items()}
    with open(args.json, "w") as f:
        json.dump({"results": results, "speedup_vs_min_devices": scaling},
                  f, indent=2)
    print(f"JSON -> {args.json}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: K<=64, devices {1,4}")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=lambda s: tuple(
        int(x) for x in s.split(",")), default=None)
    ap.add_argument("--devices", type=lambda s: tuple(
        int(x) for x in s.split(",")), default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_fleet.json")
    ap.add_argument("--base-store", default="versioned",
                    choices=("versioned", "dense"), help=argparse.SUPPRESS)
    ap.add_argument("--ef", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--faults", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--wire-format", dest="wire_format", default="csr",
                    choices=("csr", "csr_q", "dense_masked"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--client-store", dest="client_store",
                    default="resident", choices=("resident", "paged"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--pool", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--participants", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--warmup", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--model", default=None, choices=tuple(LM_PRESETS),
                    help=argparse.SUPPRESS)
    ap.add_argument("--chunk-size", dest="chunk_size", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--checkpoint", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.clients is None:
        args.clients = SMOKE_CLIENTS if args.smoke else FULL_CLIENTS
    if args.devices is None:
        args.devices = SMOKE_DEVICES if args.smoke else FULL_DEVICES
    if args.rounds is None:
        args.rounds = 5

    if args.worker:
        worker(args)
    else:
        driver(args)


if __name__ == "__main__":
    main()
