"""Paper Table XI: dynamic supervised-learning weight f(r) vs fixed 1/2 and
fixed 1/(C*M+1)."""
from benchmarks.common import csv_row, fmt_row, run_feds3a

VARIANTS = [("fixed_alpha", "fixed-1/2"), ("adaptive", "adaptive"),
            ("fixed_beta", "fixed-1/7")]


def run(mode, out):
    for scenario in mode["scenarios"]:
        for key, name in VARIANTS:
            res = run_feds3a(scenario, scale=mode["scale"],
                             rounds=mode["rounds"],
                             supervised_weight_mode=key)
            print(fmt_row(f"[T11 {scenario}] {name}", res))
            out.append(csv_row("T11", scenario, name, res))
