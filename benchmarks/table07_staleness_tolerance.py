"""Paper Table VII: impact of the staleness tolerance tau."""
from benchmarks.common import csv_row, fmt_row, run_feds3a


def run(mode, out):
    for scenario in mode["scenarios"]:
        for tau in (0, 1, 2, 3, 4):
            res = run_feds3a(scenario, scale=mode["scale"],
                             rounds=mode["rounds"], tau=tau)
            print(fmt_row(f"[T7 {scenario}] tau={tau}", res))
            out.append(csv_row("T7", scenario, f"tau={tau}", res))
