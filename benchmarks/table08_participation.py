"""Paper Table VIII: participation proportion C (incl. ART round efficiency).

C=0.1 is asynchronous FL (aggregate on first arrival), C=1 synchronous.
"""
from benchmarks.common import csv_row, fmt_row, run_feds3a


def run(mode, out):
    for scenario in mode["scenarios"]:
        for C in (0.1, 0.4, 0.5, 0.6, 1.0):
            res = run_feds3a(scenario, scale=mode["scale"],
                             rounds=mode["rounds"], C=C)
            print(fmt_row(f"[T8 {scenario}] C={C}", res))
            out.append(csv_row("T8", scenario, f"C={C}", res))
