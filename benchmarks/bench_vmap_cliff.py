"""Canary for the XLA:CPU batched-GEMM cliff behind the ``lax.map`` fallback.

``make_batched_client_epoch`` (core.pseudo_label) lowers the client axis to
``jax.lax.map`` on the CPU backend because XLA:CPU batched GEMMs degraded
superlinearly past K~4 rows when the fallback was added (measured 2x at
K=6). ROADMAP marks that workaround "revisit per JAX release": if an XLA
upgrade fixes batched-GEMM lowering, the fallback silently becomes a
de-optimization (a serial scan over clients where a parallel vmap would do)
and nothing would ever tell us. This microbenchmark is that tripwire — the
weekly jax-latest CI job runs it and FAILS LOUDLY when the fallback starts
costing real throughput.

Method: build a faithful miniature of the batched client epoch out of the
repo's own pieces — the real CNN forward (small parity config), the
scan-over-batches + ``lax.cond`` dead-step + flat-Adam structure — and time
the client axis lowered both ways (``jax.vmap`` vs ``jax.lax.map``) on the
same operands. A bare tanh-GEMM chain does NOT reproduce the effect; the
cliff lives in the full autodiff+optimizer dispatch mix, so the canary
benchmarks exactly that.

Interpretation (exit codes):

* 0, "cliff present" — vmap >= 1.5x slower than lax.map: the fallback is
  still earning its keep.
* 0, "neutral" — the ratio sits in (0.8, 1.5): the two lowerings are
  within noise of each other (expected on few-core runners, where both
  strategies serialize). The fallback costs nothing and stays — engine
  parity is pinned against its reduction order.
* 1, "FALLBACK NOW HURTS" — vmap is decisively FASTER (ratio <= 0.8):
  XLA:CPU now batches the client axis better than a serial scan. Drop the
  ``lax.map`` fallback in ``make_batched_client_epoch`` /
  ``class_histogram_batch`` and re-pin parity.
* 0, skipped — non-CPU backend (the cliff is XLA:CPU-specific).

  PYTHONPATH=src python -m benchmarks.bench_vmap_cliff
"""
from __future__ import annotations

import sys
import time

# past the measured cliff onset (K~4-6) while keeping the canary a few
# seconds on a CI core; small parity CNN so compile time stays bounded
K, B, NB = 8, 50, 4
REPEATS = 5
THRESHOLD = 0.9          # pseudo-label confidence gate (paper default)


def _build():
    import jax
    import jax.numpy as jnp

    from repro.core.pseudo_label import adam_update
    from repro.core.sparse_comm import flatten_tree, unflatten_like
    from repro.kernels.ref import masked_pseudo_ce_ref
    from repro.models.cnn import CNNConfig, cnn_forward, init_cnn

    cfg = CNNConfig(name="vmap-cliff-canary", conv_filters=(8, 8), hidden=16)
    template = init_cnn(cfg, jax.random.PRNGKey(0))
    flat0 = flatten_tree(template)

    def one_client(flat, xc, vc, lr, rng):
        xb = xc.reshape(NB, B, -1)
        vb = vc.reshape(NB, B)
        opt = {"m": jnp.zeros_like(flat), "v": jnp.zeros_like(flat),
               "t": jnp.zeros((), jnp.int32)}

        def step(carry, inp):
            flat, o, rng = carry
            xi, vi = inp
            rng, dr = jax.random.split(rng)

            def live_step(_):
                def loss_fn(fp):
                    pp = unflatten_like(fp, template)
                    logits = cnn_forward(cfg, pp, xi, train=True, rng=dr)
                    loss, _ = masked_pseudo_ce_ref(logits, THRESHOLD)
                    return jnp.sum(loss * vi) / jnp.maximum(jnp.sum(vi), 1.0)

                l, g = jax.value_and_grad(loss_fn)(flat)
                f2, o2 = adam_update(g, o, flat, lr=lr, l1=0.0)
                return f2, o2, l

            def dead_step(_):
                return flat, o, jnp.float32(0.0)

            live = jnp.sum(vi) > 0
            flat, o, l = jax.lax.cond(live, live_step, dead_step, None)
            return (flat, o, rng), l

        (flat, opt, _), losses = jax.lax.scan(step, (flat, opt, rng),
                                              (xb, vb))
        return flat, jnp.mean(losses)

    chain_vmap = jax.jit(lambda *a: jax.vmap(one_client)(*a))
    chain_map = jax.jit(
        lambda *a: jax.lax.map(lambda t: one_client(*t), a))

    key = jax.random.PRNGKey(1)
    args = (
        jnp.tile(flat0[None], (K, 1)),
        jax.random.normal(key, (K, NB * B, cfg.num_features), jnp.float32),
        jnp.ones((K, NB * B), jnp.float32),
        jnp.full((K,), 1e-3, jnp.float32),
        jax.random.split(key, K),
    )
    return jax, (chain_vmap, chain_map), args


def _time(jax, fn, args):
    jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        out = fn(*args)
    jax.block_until_ready(out[0])
    return (time.perf_counter() - t0) / REPEATS


def main():
    jax, (chain_vmap, chain_map), args = _build()
    if jax.default_backend() != "cpu":
        print(f"[bench_vmap_cliff] backend={jax.default_backend()}: the "
              f"batched-GEMM cliff is XLA:CPU-specific — skipped")
        return 0
    t_vmap = _time(jax, chain_vmap, args)
    t_map = _time(jax, chain_map, args)
    ratio = t_vmap / t_map
    print(f"[bench_vmap_cliff] jax {jax.__version__}  K={K} B={B} nb={NB}: "
          f"vmap {t_vmap*1e3:.1f} ms  lax.map {t_map*1e3:.1f} ms  "
          f"ratio x{ratio:.2f}")
    if ratio >= 1.5:
        print("cliff present: the lax.map fallback in "
              "make_batched_client_epoch is still justified")
        return 0
    if ratio > 0.8:
        print("neutral (ratio in the 0.8-1.5 band): the two lowerings are "
              "within noise — the fallback costs nothing, keep it (engine "
              "parity is pinned against its reduction order)")
        return 0
    print("FALLBACK NOW HURTS: vmap decisively beats lax.map on XLA:CPU "
          f"(x{ratio:.2f}). Drop the lax.map fallback in "
          "core/pseudo_label.py (make_batched_client_epoch, "
          "class_histogram_batch), let the client axis vmap on every "
          "backend, and re-pin engine parity.", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
