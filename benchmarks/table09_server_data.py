"""Paper Table IX: size of the server's labeled dataset (1..7% of train)."""
from benchmarks.common import csv_row, fmt_row, run_feds3a


def run(mode, out):
    for scenario in mode["scenarios"]:
        for frac in (0.01, 0.02, 0.04, 0.05, 0.07):
            res = run_feds3a(scenario, scale=mode["scale"],
                             rounds=mode["rounds"], server_frac=frac)
            print(fmt_row(f"[T9 {scenario}] server={frac:.0%}", res))
            out.append(csv_row("T9", scenario, f"server={frac:.0%}", res))
