"""Paper Table VI: adaptive learning rate + round-weight function h(r)."""
from benchmarks.common import csv_row, fmt_row, run_feds3a

VARIANTS = ["non_adaptive", "constant", "logarithmic", "polynomial",
            "exponential_smoothing", "exponential"]


def run(mode, out):
    for scenario in mode["scenarios"]:
        for fn in VARIANTS:
            kw = (dict(adaptive_lr=False) if fn == "non_adaptive"
                  else dict(adaptive_lr=True, round_weight_function=fn))
            res = run_feds3a(scenario, scale=mode["scale"],
                             rounds=mode["rounds"], **kw)
            print(fmt_row(f"[T6 {scenario}] {fn}", res))
            out.append(csv_row("T6", scenario, fn, res))
