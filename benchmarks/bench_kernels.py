"""Microbenchmarks for the Pallas kernel wrappers (interpret on CPU) and
their jnp oracles. Prints name,us_per_call,derived CSV lines."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref as R


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run(mode, out):
    rng = jax.random.PRNGKey(0)
    logits = jax.random.normal(rng, (4096, 9))
    us_ref = _time(jax.jit(lambda l: R.masked_pseudo_ce_ref(l, 0.95)), logits)
    out.append(f"kern,cpu,masked_pseudo_ce_ref,{us_ref:.0f}")
    print(f"masked_pseudo_ce ref       {us_ref:10.0f} us/call")

    x = jax.random.normal(rng, (1 << 20,))
    us = _time(jax.jit(lambda v: R.sparse_delta_ref(
        jnp.pad(v, (0, 0)), 0.5)), x)
    out.append(f"kern,cpu,sparse_delta_ref,{us:.0f}")
    print(f"sparse_delta ref (1M)      {us:10.0f} us/call")

    d = jax.random.normal(rng, (6, 1 << 18))
    w = jnp.arange(1, 7, dtype=jnp.float32) / 21
    us = _time(jax.jit(R.staleness_agg_ref), d, w)
    out.append(f"kern,cpu,staleness_agg_ref,{us:.0f}")
    print(f"staleness_agg ref (6x256k) {us:10.0f} us/call")

    q = jax.random.normal(rng, (1, 256, 4, 64))
    k = jax.random.normal(rng, (1, 256, 4, 64))
    us = _time(jax.jit(lambda a, b: R.flash_attention_ref(a, b, b)), q, k)
    out.append(f"kern,cpu,flash_attention_ref,{us:.0f}")
    print(f"flash_attention ref        {us:10.0f} us/call")
