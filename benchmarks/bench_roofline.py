"""Roofline summary (§Roofline deliverable): reads the dry-run artifacts in
results/ (produced by `python -m repro.launch.dryrun --all --out ...`) and
prints the per-(arch x shape) three-term roofline table."""
import glob
import json
import os


def run(mode, out):
    paths = sorted(glob.glob(os.path.join("results", "dryrun*.json")))
    if not paths:
        print("bench_roofline: no results/dryrun*.json found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod "
              "--out results/dryrun_pod.json` first (skipping)")
        return
    rows = []
    for p in paths:
        rows.extend(json.load(open(p)))
    print(f"{'case':44s} {'mesh':8s} {'comp_ms':>9s} {'mem_ms':>10s} "
          f"{'coll_ms':>10s} {'bound':>10s} {'useful':>7s}")
    for r in rows:
        rl = r["roofline"]
        name = f"{r['arch']}:{r['shape']}"
        print(f"{name:44s} {r['mesh']:8s} {rl['t_compute_ms']:9.1f} "
              f"{rl['t_memory_ms']:10.1f} {rl['t_collective_ms']:10.1f} "
              f"{rl['bottleneck']:>10s} {rl['useful_flops_ratio']:7.3f}")
        out.append(
            f"roofline,{r['mesh']},{name},{rl['t_compute_ms']:.1f},"
            f"{rl['t_memory_ms']:.1f},{rl['t_collective_ms']:.1f},"
            f"{rl['bottleneck']},{rl['useful_flops_ratio']:.3f}")
