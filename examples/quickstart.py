"""Quickstart: train FedS3A for a few rounds on the synthetic CIC-IDS-2017
basic (non-IID) scenario and print per-round metrics.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset


def main():
    print("building synthetic CIC-IDS-2017 (basic / non-IID scenario)...")
    data = make_dataset("basic", scale=0.008, seed=0)
    for i, (c, e) in enumerate(zip(data["clients"], data["entropy"])):
        print(f"  client {i}: {len(c['x']):5d} samples, entropy {e:.3f}")
    print(f"  server:   {len(data['server']['x'])} labeled samples")

    cfg = FedS3AConfig(rounds=8, C=0.6, tau=2)
    trainer = FedS3ATrainer(data, cfg)
    print(f"\nFedS3A: C={cfg.C} tau={cfg.tau} "
          f"staleness={cfg.staleness_function} groups={cfg.num_groups}")
    for _ in range(cfg.rounds):
        log = trainer.run_round()
        m = trainer.evaluate()
        print(f"  round {log.round:2d}  t={log.time:7.1f}s  art={log.art:6.1f}s"
              f"  participants={log.participants}  forced={log.forced}"
              f"  acc={m['accuracy']:.4f}  f1={m['f1']:.4f}")
    final = trainer.evaluate()
    print(f"\nfinal: acc={final['accuracy']:.4f} f1={final['f1']:.4f} "
          f"fpr={final['fpr']:.4f}  ACO={trainer.comm.aco:.2f} "
          f"(communication cut by {(1 - trainer.comm.aco) * 100:.0f}%)")


if __name__ == "__main__":
    main()
