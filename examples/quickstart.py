"""Quickstart: train FedS3A for a few rounds on the synthetic CIC-IDS-2017
basic (non-IID) scenario and print per-round metrics.

  PYTHONPATH=src python examples/quickstart.py

Choosing an engine
------------------
``FedS3AConfig(engine=...)`` selects how a round is executed; all three
engines run the same algorithm (the parity suite pins them together):

* ``engine="sequential"`` — one client at a time; the reference
  implementation. Best for debugging and for compute-bound CPU training of
  large models, where batching buys nothing.
* ``engine="batched"`` — all participants as a stacked (K, N) flat matrix,
  one jitted call per round stage. Best on a single accelerator, or on CPU
  when the model is small enough that round overhead dominates
  (~3.5x per round measured).
* ``engine="sharded"`` — the fleet engine: the (K, N) stacks are sharded
  row-wise across all visible devices with shard_map over a ``clients``
  mesh, the aggregation is one psum, and grouping runs a jitted on-device
  k-means, so a round is device-resident end to end. Use it to simulate
  thousands of clients; on a CPU-only host, launch with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get 8
  simulated devices (see benchmarks/bench_fleet.py).
* ``engine=None`` (default) — auto: on multi-device hosts the sharded
  engine, on a single accelerator (or a single-device CPU host with a
  small model) the batched engine, and for compute-bound CPU training of
  larger models (>~300k params, e.g. the paper CNN) the sequential
  reference regardless of device count — pass ``engine="sharded"``
  explicitly to fleet-shard a large model on CPU.
"""
from repro.core import FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset


def main():
    print("building synthetic CIC-IDS-2017 (basic / non-IID scenario)...")
    data = make_dataset("basic", scale=0.008, seed=0)
    for i, (c, e) in enumerate(zip(data["clients"], data["entropy"])):
        print(f"  client {i}: {len(c['x']):5d} samples, entropy {e:.3f}")
    print(f"  server:   {len(data['server']['x'])} labeled samples")

    cfg = FedS3AConfig(rounds=8, C=0.6, tau=2)
    trainer = FedS3ATrainer(data, cfg)
    print(f"\nFedS3A: C={cfg.C} tau={cfg.tau} "
          f"staleness={cfg.staleness_function} groups={cfg.num_groups} "
          f"engine={trainer.engine} (auto)")
    for _ in range(cfg.rounds):
        log = trainer.run_round()
        m = trainer.evaluate()
        print(f"  round {log.round:2d}  t={log.time:7.1f}s  art={log.art:6.1f}s"
              f"  participants={log.participants}  forced={log.forced}"
              f"  acc={m['accuracy']:.4f}  f1={m['f1']:.4f}")
    final = trainer.evaluate()
    print(f"\nfinal: acc={final['accuracy']:.4f} f1={final['f1']:.4f} "
          f"fpr={final['fpr']:.4f}  ACO={trainer.comm.aco:.2f} "
          f"(communication cut by {(1 - trainer.comm.aco) * 100:.0f}%)")


if __name__ == "__main__":
    main()
