"""Quickstart: train FedS3A for a few rounds on the synthetic CIC-IDS-2017
basic (non-IID) scenario and print per-round metrics.

  PYTHONPATH=src python examples/quickstart.py

Choosing an engine
------------------
``FedS3AConfig(engine=...)`` selects how a round is executed; all three
engines run the same algorithm (the parity suite pins them together):

* ``engine="sequential"`` — one client at a time; the reference
  implementation. Best for debugging and for compute-bound CPU training of
  large models, where batching buys nothing.
* ``engine="batched"`` — all participants as a stacked (K, N) flat matrix,
  one jitted call per round stage. Best on a single accelerator, or on CPU
  when the model is small enough that round overhead dominates
  (~3.5x per round measured).
* ``engine="sharded"`` — the fleet engine: the (K, N) stacks are sharded
  row-wise across all visible devices with shard_map over a ``clients``
  mesh, the aggregation is one psum, and grouping runs a jitted on-device
  k-means, so a round is device-resident end to end. Use it to simulate
  thousands of clients; on a CPU-only host, launch with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get 8
  simulated devices (see benchmarks/bench_fleet.py).
* ``engine=None`` (default) — auto: on multi-device hosts the sharded
  engine — provided the round carries at least 4 participants per device
  (``feds3a.MIN_SHARD_ROWS``): tinier rounds lose more to the psum/
  collective overhead than the extra devices return, so they fall back to
  batched (measured at K=8, D=4 on CPU). A single accelerator (or a
  single-device CPU host with a small model) gets the batched engine, and
  compute-bound CPU training of larger models (>~300k params, e.g. the
  paper CNN) keeps the sequential reference regardless of device count —
  pass ``engine="sharded"`` explicitly to fleet-shard a large model on CPU.

Wire format
-----------
``FedS3AConfig(wire_format=...)`` selects how sparse diffs travel:

* ``"csr"`` (default) — the compacted wire format: every upload and
  distribution message is a real (values, indices, row_ptr) CSR payload
  produced by the compaction kernel, so the reported bytes-on-wire /ACO is
  the byte size of arrays that actually exist, and exact zeros never
  travel. In the paper regime (this quickstart: the full CNN, real
  training) that measures ACO ≈ 0.5 — a ~50% cut vs dense at the default
  p0.2 sparsity. At toy scale the kept fraction runs high (ACO 0.58-0.64
  in the small-CNN fleet benchmark cells): after only 1-2 Adam steps the
  delta magnitudes are nearly uniform, so the p0.2 quantile threshold
  ties across much of the row — same effect the batched-engine tests
  document for the counted format. Each row is bounded by
  a static capacity (~2.5x the target keep fraction of N); mass past the
  capacity spills into the error-feedback residual when
  ``error_feedback=True`` and is dropped (the paper's lossy scheme)
  otherwise. Under EF the per-client residual itself lives in a
  capacity-bounded CSR store — ``residual_frac`` of N entries kept by
  magnitude (default 0.25, i.e. 2N bytes/client instead of 4N dense;
  ``residual_frac=1.0`` recovers lossless EF) — which is what lets the
  sharded engine carry fleet-scale per-client state without a dense
  (M, N) residual matrix.
* ``"csr_q"`` — the quantized + packed format, layered on the same
  compaction: values travel as int8 with one f32 absmax scale per row
  (``scale = absmax / 127``), and column indices as int16 offsets within
  their 512-column block plus a per-row int16 block-count table —
  3 bytes per stored element instead of 8, so the same kept fraction
  moves at ~0.375x the f32 CSR payload (~2.7x fewer bytes; the CI gate
  pins <=0.4x at K in {512, 2048}). The server aggregates by a
  dequantizing scatter-add fused into the weighted client sum, and the
  versioned base store keeps its chain deltas in the quantized wire form
  while the ring reconstructions every client rebuilds stay canonical
  f32. Quantization is lossy by design: with ``error_feedback=True`` the
  rounding error (at most half a quantization step per element) spills
  into the same EF residual as the sparsification overflow and is
  re-offered next round; without EF it is dropped like any other
  sub-threshold mass. ``q_dtype="fp16"`` selects a half-precision
  fallback (5 bytes/element, scales become identity and are not shipped)
  for deltas whose dynamic range genuinely exceeds int8.
* ``"dense_masked"`` — the pre-compaction reference: masked dense deltas
  move between engines and ACO counts 8 bytes per threshold survivor
  without materializing a payload. Kept for debugging and as the parity
  baseline.

Base store
----------
``FedS3AConfig(base_store=...)`` selects how the server remembers what each
client holds (every engine supports both):

* ``"versioned"`` (default) — the staleness-windowed store: the server
  keeps a ring of the last ``tau + 2`` canonical reconstructions ``R_v``
  plus one compacted chain delta per round transition, and a client's base
  is just a ring lookup by its ``base_version`` — clients at the same
  version hold the bit-identical model. Distribution becomes a chain-delta
  broadcast — each transition payload goes on the wire once per round (at
  most ``tau + 1`` of them) and every listening client picks up the suffix
  it needs — instead of one sparse encode per target, and
  server base memory is O(tau * N + M) instead of the O(M * N) per-client
  state the dense store needs — the difference between thousands and
  millions of clients fitting on one parameter server.
* ``"dense"`` — the legacy layout (per-client base trees / rows / the
  (M, N) matrix) with one distribution encode per target. Kept as the
  parity-pinned reference.

When do the two differ numerically? Only through sparsification loss.
With ``sparse_comm=False`` every chain delta is an exact dense copy, so
``R_v`` equals the aggregated global model bit-for-bit and the two stores
produce identical runs (pinned in tests/test_base_store.py). With
sparsification on, the dense store lets every client accumulate its OWN
lossy approximation (each per-target encode thresholds against that
client's base), while the versioned store gives all same-version clients
one shared canonical approximation ``R_v = R_{v-1} + decode(chain)``. Both
sit within the sparsification error budget of the true global model; they
are equally faithful to the paper, which specifies the threshold rule but
not server-side bookkeeping. The cross-engine parity matrix therefore pins
each store against its own sequential reference.

Fault injection & degraded rounds
---------------------------------
Real IoT fleets crash mid-run, drop uploads, and churn. Attach a traffic
model to simulate that (requires ``base_store="versioned"``)::

    from repro.core import FedS3AConfig, TrafficModel, REFERENCE_CHURN

    cfg = FedS3AConfig(
        rounds=50,
        traffic=REFERENCE_CHURN,     # crash 10%, upload loss 5%, churn
        round_deadline=700.0,        # wall-clock cap per round (sim secs)
        quorum_floor=2,              # aggregate >=2 uploads at deadline
    )

``TrafficModel`` draws, per client run, from a dedicated fault RNG
(separate stream from latency jitter, so the fault trace is identical
across engines): heavy-tailed lognormal latency multipliers
(``tail_sigma``), crash-mid-run (the client retries from its persisted
base — staleness emerges naturally), upload loss (the update vanishes
after compute; the server redistributes at the next boundary and the
bytes ledger never books the lost payload), and exponential online/
offline churn (``mean_online`` / ``mean_offline``) plus ``late_join_frac``
clients that start offline.

The scheduler degrades gracefully instead of hanging: when the
participation target ``k = ceil(C*M)`` cannot be met by
``round_deadline``, the server aggregates whatever quorum it has (down to
``quorum_floor``) and marks the round degraded; if the whole fleet is
gone and the floor is unreachable it raises ``FleetStalledError`` with a
diagnosis rather than spinning on an empty heap. A client that rejoins
after its ``base_version`` was evicted from the versioned ring gets an
explicit full-model resync (booked as a dense unicast); recent rejoiners
are served the cheap chain-delta suffix instead.

Per-round degradation lands on the ``RoundLog`` (``degraded``,
``deadline_hit``, ``quorum``/``target_k``, ``crashes``, ``lost``,
``departed``, ``rejoined``, ``resynced``) and ``train()`` returns an
aggregate ``fleet`` health dict (``degraded_rounds``,
``mean_quorum_frac``, ``resyncs``, ...) — bit-identical across all three
engines for the same seed (pinned in tests/test_chaos.py).

Checkpoint, resume & corrupted uploads
--------------------------------------
Long fleet simulations should survive a SIGKILL. Point the trainer at a
checkpoint directory and it snapshots the COMPLETE round-boundary state
every ``checkpoint_every`` rounds::

    cfg = FedS3AConfig(
        rounds=500,
        traffic=REFERENCE_CHURN,
        checkpoint_dir="ckpts/run0",   # requires base_store="versioned"
        checkpoint_every=10,
    )
    trainer = FedS3ATrainer(data, cfg)
    trainer.train()

    # ...process dies; later, in a fresh process:
    trainer = FedS3ATrainer(data, cfg)
    done = trainer.restore()           # newest COMPLETE checkpoint
    trainer.train(cfg.rounds - done)   # bit-identical to never crashing

A snapshot carries everything a round touches — global model + Adam
moments, the error-feedback residuals (every layout: resident rows,
sharded matrix, capacity-bounded CSR, paged host pages), the versioned
base-store ring/chain/version maps, both scheduler heaps and BOTH RNG
streams (latency jitter and fault traffic, down to their 128-bit PCG64
state words), the byte ledgers, participation counters and round logs —
so ``train(50)`` and ``train(25) -> kill -9 -> restore() -> train(25)``
produce the same model, ACO, fault trace and fleet health to the bit
(pinned across engines x stores x wire formats in
tests/test_fleet_ckpt.py, and end-to-end under real SIGKILL in
tests/test_kill_resume.py; CI's kill-resume job varies the kill timing
via ``KILL_SEED``).

Writes are crash-consistent: section files are written plainly, then a
MANIFEST carrying a sha256 digest of every section commits the
checkpoint LAST by tmp-write + fsync + atomic rename — the single
commit and durability point, so a torn or never-flushed section is
indistinguishable from bit-rot and equally detected. ``restore()``
verifies digests and falls back past a torn or bit-rotted newest
checkpoint to the previous good one (retention keeps two). A config
that differs from the one that wrote the checkpoint (engine, wire
format, store, fleet size, seed, ...) is refused via a fingerprint
check rather than silently diverging. ``train()`` checkpoints through
a background writer (``save_checkpoint(wait=False)``): JAX arrays are
immutable, so the snapshot captures device references for free and the
host transfer + serialization + disk protocol overlap the next rounds
— with ``checkpoint_every=5`` throughput stays within 5% of an
uncheckpointed run at every fleet size (gated in
benchmarks/check_regression.py).

Transport faults extend beyond loss: ``TrafficModel(corrupt_prob=...)``
makes that fraction of delivered uploads arrive MALFORMED. The server's
wire-integrity validation (``SparseComm.validate_payload``) checks every
CSR-family payload — row-pointer monotonicity, index bounds, NaN/inf
values or scales, truncated buffers, wrong dtypes — and quarantines
offenders through the exact lost-upload path: nothing is aggregated, no
bytes are booked, capacity-spill residuals are retired, and the client
rebases at the next broadcast. Quarantines land on ``RoundLog.corrupted``
and aggregate as ``fleet["quarantined"]``; the trace is bit-identical
across engines (tests/test_wire_integrity.py).

Chunked parameter axis & per-layer sparsity
-------------------------------------------
Every engine flattens parameters to one length-N vector and stacks the
round's K participants as (K, N); for the paper CNN (N ~ 1e5) the per-stage
(K, N) delta buffers are free, but for the real LM configs the repo carries
they are the device-memory wall. ``FedS3AConfig(chunk_size=...)`` partitions
the flat axis into chunks **aligned to parameter-leaf boundaries**
(``core.param_layout.ParamLayout``) and streams every
(K, N)-materializing stage — the sparse-diff encode, the EF residual
update, the versioned-ring advance, the fused server blends — one chunk at
a time, so peak device delta memory is O(K * chunk_size) instead of
O(K * N) (``trainer.peak_delta_device_bytes()`` reports the bound; the CI
regression gate pins it flat in N). With ``model=<a configs ModelConfig>``
the same trainer federates a real transformer as a final-token classifier
(see examples/fl_large_model.py for the reduced qwen2-1.5b at 1.3M
params); ``cnn=`` keeps driving the paper CNN, chunked or not.

Three contracts worth knowing:

* ``chunk_size=0`` (the default) and any chunk_size >= N are exactly the
  historical flat path — the degenerate single-chunk layout resolves to no
  layout at all, and the parity suite pins those runs bit-identical to the
  seed behaviour per engine and wire format.
* A real multi-chunk run is NOT bit-identical to flat by design: the p0.2
  quantile thresholds become per-chunk statistics instead of per-row
  globals. That is also the feature: ``layer_keep_frac={"embed": 0.05}``
  gives any leaf(-name substring) its own keep fraction, and leaf
  alignment guarantees an overridden leaf never shares a chunk — per-layer
  sparsity with no extra kernel work. ``wire_breakdown()["layout"]``
  reports the resolved layout truthfully.
* Keep the chunk count modest (a handful to a few tens, i.e. pick
  chunk_size ~ N/10): the chunk loop is unrolled inside the jitted round
  bodies, so XLA compile time scales with the number of chunks — hundreds
  of chunks compile for minutes for no extra memory win. Chunked rounds
  require the default ``base_store="versioned"`` and a CSR-family wire
  format (csr / csr_q).

Client state paging
-------------------
``FedS3AConfig(client_store=...)`` selects where per-client state (the
error-feedback residual rows and participation/staleness counters) lives:

* ``"resident"`` (default) — on-device, sized by the fleet: the EF store
  is an (M, rcap) device matrix, so device memory grows with M whether or
  not a client participates. Kept as the parity-pinned reference; right
  whenever the whole fleet fits.
* ``"paged"`` — host-resident numpy pages plus a device window holding
  only the round's K participants: the round prologue gathers the
  participants' residual rows host->device, the epilogue scatters the
  updated rows back, and device client-state bytes are O(K * rcap) — flat
  in M (the CI scale gate pins a demonstrated M=1,000,000-client round).
  Requires ``base_store="versioned"`` (the paged layout keeps no
  per-client base state at all — a client's base is its ring version,
  already host-side). Paged runs are bit-identical to resident runs
  (pinned per engine in tests/test_engine_parity.py).

  Two operational notes. First, writes are double-buffered: the epilogue
  scatter is ENQUEUED and drained at the next round's prologue (so the
  write-back overlaps the next round's work) — host pages are stale until
  then, and any direct read through the store (``residual_row``,
  ``gather_*``) flushes first to stay coherent. Second,
  ``FedS3AConfig(paged_dir=...)`` backs the pages with memory-mapped
  ``.npy`` files instead of anonymous memory: fleets whose residual store
  exceeds RAM spill to disk, and the OS pages in only the rows each round
  touches.

Paging pays when M >> K — the window costs two host<->device copies per
round but shrinks device state by M/K; at M = K (every client every
round) it is pure overhead, so the regression gate only holds paged cells
to 0.9x resident throughput. For fleet-scale datasets,
``make_fleet_dataset(pool=P)`` materializes only P distinct client shards
and aliases them cyclically, so the data footprint stays O(P) while the
fleet is M clients wide.

CI runs ``benchmarks/check_regression.py`` against the committed
BENCH_fleet.json on every PR, failing on >30% rounds/sec regression or any
bytes-on-wire increase — if you touch the comm path, refresh the baseline
with ``python -m benchmarks.bench_fleet``.

Environment knobs (used by the CI examples smoke job): ``EXAMPLES_ROUNDS``
overrides the round count, ``EXAMPLES_SCALE`` the dataset scale.
"""
import os

from repro.core import FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset

ROUNDS = int(os.environ.get("EXAMPLES_ROUNDS", "8"))
SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.008"))


def main():
    print("building synthetic CIC-IDS-2017 (basic / non-IID scenario)...")
    data = make_dataset("basic", scale=SCALE, seed=0)
    for i, (c, e) in enumerate(zip(data["clients"], data["entropy"])):
        print(f"  client {i}: {len(c['x']):5d} samples, entropy {e:.3f}")
    print(f"  server:   {len(data['server']['x'])} labeled samples")

    cfg = FedS3AConfig(rounds=ROUNDS, C=0.6, tau=2)
    trainer = FedS3ATrainer(data, cfg)
    print(f"\nFedS3A: C={cfg.C} tau={cfg.tau} "
          f"staleness={cfg.staleness_function} groups={cfg.num_groups} "
          f"engine={trainer.engine} (auto)")
    for _ in range(cfg.rounds):
        log = trainer.run_round()
        m = trainer.evaluate()
        print(f"  round {log.round:2d}  t={log.time:7.1f}s  art={log.art:6.1f}s"
              f"  participants={log.participants}  forced={log.forced}"
              f"  acc={m['accuracy']:.4f}  f1={m['f1']:.4f}")
    final = trainer.evaluate()
    print(f"\nfinal: acc={final['accuracy']:.4f} f1={final['f1']:.4f} "
          f"fpr={final['fpr']:.4f}  ACO={trainer.comm.aco:.2f} "
          f"(communication cut by {(1 - trainer.comm.aco) * 100:.0f}%)")


if __name__ == "__main__":
    main()
