"""Serving demo: batched prefill + greedy decode with every cache variety in
the zoo (KV cache, MLA latent cache, mamba/xLSTM recurrent state), on reduced
configs. The identical serve_step lowers for decode_32k / long_500k on the
production mesh.

  PYTHONPATH=src python examples/serve_demo.py [--arch jamba-1.5-large-398b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.training.steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-1.5-large-398b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)
    B, K = args.batch, args.prompt_len
    cache_len = K + args.gen

    batch = {"tokens": jax.random.randint(rng, (B, K), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.num_encoder_positions, cfg.d_model))
    if cfg.num_vision_patches:
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.num_vision_patches, cfg.d_model))
    P = cfg.num_vision_patches or 0

    print(f"arch={args.arch} (reduced) — prefill {K} tokens x{B}, "
          f"decode {args.gen}")
    t0 = time.time()
    last, cache = jax.jit(
        lambda p, b: lm.prefill(cfg, p, b, cache_len + P))(params, batch)
    print(f"  prefill: {time.time()-t0:.2f}s")

    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, logits, cache = serve(params, cache, tok, jnp.int32(P + K + i))
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(out, axis=1)
    print(f"  decode: {args.gen-1} steps in {dt:.2f}s "
          f"({B*(args.gen-1)/max(dt,1e-9):.1f} tok/s batch-aggregate)")
    print(f"  sample continuation (client 0): {toks[0].tolist()}")


if __name__ == "__main__":
    main()
