"""Paper Table XII in miniature: FedS3A vs FedAvg-SSL (partial/all) vs
FedAsync-SSL vs the Local-SSL ceiling, on the non-IID basic scenario.

  PYTHONPATH=src python examples/compare_baselines.py

Environment knobs (used by the CI examples smoke job): ``EXAMPLES_ROUNDS``
overrides the round count, ``EXAMPLES_SCALE`` the dataset scale.
"""
import os

from repro.core import (FedAsyncSSL, FedAvgSSL, FedS3AConfig, FedS3ATrainer,
                        LocalSSL)
from repro.data import make_dataset

ROUNDS = int(os.environ.get("EXAMPLES_ROUNDS", "8"))
SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.008"))


def main():
    data = make_dataset("basic", scale=SCALE, seed=0)
    cfg = FedS3AConfig(rounds=ROUNDS)

    rows = []
    tr = FedS3ATrainer(data, cfg)
    rows.append(("FedS3A", tr.train()))
    rows.append(("FedAvg-SSL-Partial", FedAvgSSL(data, cfg, mode="partial").train()))
    rows.append(("FedAvg-SSL-All", FedAvgSSL(data, cfg, mode="all").train()))
    rows.append(("FedAsync-SSL", FedAsyncSSL(data, cfg).train(cfg.rounds * 4)))
    rows.append(("Local-SSL (ceiling)", LocalSSL(data, cfg).train()))

    print(f"\n{'algorithm':22s} {'acc':>7s} {'f1':>7s} {'fpr':>7s} "
          f"{'ART(s)':>8s} {'ACO':>6s}")
    for name, res in rows:
        m = res["metrics"]
        print(f"{name:22s} {m['accuracy']:7.4f} {m['f1']:7.4f} "
              f"{m['fpr']:7.4f} {res['art']:8.1f} {res['aco']:6.2f}")


if __name__ == "__main__":
    main()
