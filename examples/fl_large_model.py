"""FedS3A as a first-class feature of the distributed runtime: run the
paper's federated round over a REAL model-zoo architecture (reduced size on
CPU; the same code lowers onto the 256/512-chip production mesh — see
`python -m repro.launch.dryrun --fl`).

Clients map to the data mesh axis; the staleness-weighted, participation-
masked aggregation is one weighted reduction (DESIGN.md §3).

  PYTHONPATH=src python examples/fl_large_model.py [--arch qwen2-1.5b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.distributed_fl import make_fl_train_step
from repro.models import lm
from repro.training.steps import lm_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) "
          f"M={args.clients} clients")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)

    M, LS, B, S = args.clients, 2, 2, 64
    step = jax.jit(make_fl_train_step(
        cfg, num_clients=M, lr=5e-3, local_steps=LS, keep_frac=0.2,
        impl="ref", f_weight=0.0))

    eval_batch = {"tokens": jax.random.randint(rng, (2, S), 0, cfg.vocab_size)}
    for r in range(args.rounds):
        rng, k = jax.random.split(rng)
        batch = {"tokens": jax.random.randint(k, (M, LS, B, S), 0,
                                              cfg.vocab_size)}
        # semi-async: client M-1 misses this round; client 1 is one round stale
        mask = jnp.ones((M,)).at[M - 1].set(0.0)
        staleness = jnp.zeros((M,)).at[1].set(1.0)
        sizes = jnp.arange(1, M + 1, dtype=jnp.float32)
        params, wsum = step(params, batch, mask, staleness, sizes)
        loss = lm_loss(cfg, params, eval_batch, impl="ref")
        print(f"  round {r}: participation={M-1}/{M}, "
              f"aggregate weight sum={float(wsum):.2f}, "
              f"eval loss={float(loss):.4f}")
    print("done — the same fl_step lowers on the (2,16,16) production mesh "
          "via `python -m repro.launch.dryrun --fl --mesh multipod`")


if __name__ == "__main__":
    main()
