"""FedS3A over a REAL model-zoo architecture: the reduced qwen2-1.5b
transformer (~1.3M parameters) runs the paper's full faulted federated
round — semi-async scheduling, pseudo-labeling, group k-means aggregation,
sparse-diff comm, churn/crash/deadline faults — through the SAME
``FedS3ATrainer`` the CNN path uses, via the chunked parameter axis.

``FedS3AConfig(model=<ModelConfig>, chunk_size=...)`` partitions the flat
parameter vector into leaf-aligned chunks and streams every
(K, N)-materializing round stage chunk by chunk, so peak device delta
memory is O(K * chunk_size), not O(K * N) — the regression gate
(benchmarks/check_regression.py) pins it flat in N across model sizes.
Keep the chunk count modest (a handful to a few tens): the per-chunk loop
is unrolled inside the jitted round bodies, so compile time scales with
the number of chunks, not with N.

  PYTHONPATH=src python examples/fl_large_model.py [--arch qwen2-1.5b]

Environment knobs (used by the CI examples smoke job): ``EXAMPLES_ROUNDS``
overrides the round count, ``EXAMPLES_LM_CLIENTS`` the fleet width,
``EXAMPLES_LM_CHUNKS`` the target chunk count.
"""
import argparse
import os

from repro.configs import get_config, load_all
from repro.core import FedS3AConfig, FedS3ATrainer, TrafficModel
from repro.data import make_lm_dataset

ROUNDS = int(os.environ.get("EXAMPLES_ROUNDS", "6"))
CLIENTS = int(os.environ.get("EXAMPLES_LM_CLIENTS", "8"))
CHUNKS = int(os.environ.get("EXAMPLES_LM_CHUNKS", "6"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--clients", type=int, default=CLIENTS)
    args = ap.parse_args()

    load_all()
    cfg_model = get_config(args.arch).reduced()
    n = cfg_model.param_count()
    print(f"arch={args.arch} reduced: {cfg_model.num_layers}L "
          f"d={cfg_model.d_model} vocab={cfg_model.vocab_size} "
          f"-> {n:,} params, M={args.clients} clients")

    data = make_lm_dataset(args.clients, vocab_size=cfg_model.vocab_size,
                           seq_len=16, num_classes=8,
                           samples_per_client=48, seed=0)
    print(f"  server: {len(data['server']['x'])} labeled, "
          f"test: {len(data['test']['x'])}")

    chunk_size = -(-n // CHUNKS)
    cfg = FedS3AConfig(
        model=cfg_model, chunk_size=chunk_size,
        rounds=args.rounds, C=0.5, tau=2, batch_size=16, lr=5e-4,
        error_feedback=True,
        traffic=TrafficModel(crash_rate=0.05, upload_loss=0.05),
        round_deadline=2000.0, quorum_floor=1,
        seed=0,
    )
    trainer = FedS3ATrainer(data, cfg)
    lay = trainer.layout
    print(f"\nlayout: {lay.num_chunks} chunks "
          f"(max {lay.max_chunk:,}, min {min(lay.sizes):,}) over "
          f"n={lay.n:,}; engine={trainer.engine}")
    print(f"peak device delta bytes: "
          f"{trainer.peak_delta_device_bytes():,} "
          f"(dense K*N would be "
          f"{4 * trainer.store.ring.shape[1] * max(int(cfg.C * args.clients), 1):,})")

    for _ in range(cfg.rounds):
        log = trainer.run_round()
        m = trainer.evaluate()
        flags = "degraded " if log.degraded else ""
        print(f"  round {log.round:2d}  quorum={log.quorum}/{log.target_k}"
              f"  crashes={log.crashes}  lost={len(log.lost)}  {flags}"
              f"acc={m['accuracy']:.4f}")
    final = trainer.evaluate()
    wb = trainer.comm.wire_breakdown()
    print(f"\nfinal: acc={final['accuracy']:.4f}  ACO={trainer.comm.aco:.3f}")
    print(f"wire layout: {wb['layout']}")


if __name__ == "__main__":
    main()
