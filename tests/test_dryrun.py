"""Dry-run machinery: small-mesh lower+compile in a subprocess (the forced
device count must land before jax init), plus the HLO cost model."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code, n_devices=8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=540)


@pytest.mark.slow
def test_small_mesh_compile_train_and_decode():
    code = """
import jax
from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import build_case
from repro.distributed.sharding import jit_shardings, use_mesh
mesh = make_test_mesh((2, 2), ("data", "model"))
for arch in ("qwen2-1.5b", "xlstm-125m"):
    cfg = get_config(arch).reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, name=cfg.name)
    for shape in ("train_4k", "decode_32k"):
        case = build_case(cfg, shape, mesh)
        with use_mesh(mesh):
            c = jax.jit(case.step_fn,
                        in_shardings=jit_shardings(mesh, case.in_shardings)
                        ).lower(*case.args).compile()
        assert c.memory_analysis() is not None
        print("OK", arch, shape)
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.count("OK") == 4


def test_hlo_cost_model_exact_on_known_program():
    code = """
import jax, jax.numpy as jnp
from jax import lax
from repro.analysis.hlo_cost import analyze_text
def f(a, bs):
    def body(c, b):
        return c, a @ b
    _, ys = lax.scan(body, None, bs)
    return ys
a = jnp.zeros((64, 128), jnp.float32)
bs = jnp.zeros((5, 128, 256), jnp.float32)
c = jax.jit(f).lower(a, bs).compile()
r = analyze_text(c.as_text())
expect = 5 * 2 * 64 * 128 * 256
assert abs(r["flops"] - expect) / expect < 1e-6, r["flops"]
print("COST_OK")
"""
    r = _run(code, n_devices=1)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COST_OK" in r.stdout


def test_collective_parse():
    from repro.analysis.hlo_cost import analyze_text
    hlo = """
HloModule test

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[32,16]{1,0} all-gather(%p), dimensions={0}
  %slice = f32[16,16]{1,0} slice(%ag), slice={[0:16], [0:16]}
  ROOT %ar = f32[16,16]{1,0} all-reduce(%slice), to_apply=%add
}
"""
    r = analyze_text(hlo)
    assert r["collectives"]["all-gather"] == 32 * 16 * 4
    assert r["collectives"]["all-reduce"] == 16 * 16 * 4
