import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path, rng):
    tree = {
        "params": {"w": jax.random.normal(rng, (4, 5)),
                   "b": jnp.zeros((5,), jnp.bfloat16)},
        "step": 7,
        "lr": 1e-4,
    }
    p = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(p, tree)
    like = jax.tree.map(lambda x: x, tree)
    out = load_checkpoint(p, like)
    np.testing.assert_allclose(np.asarray(out["params"]["w"], np.float32),
                               np.asarray(tree["params"]["w"], np.float32))
    assert out["step"] == 7
    assert out["params"]["b"].dtype == jnp.bfloat16


def test_dtype_mismatch_raises_unless_cast(tmp_path, rng):
    """A checkpoint reloaded into a template with a different leaf dtype
    must refuse (silent f32->f16 reload corrupts training invisibly)
    unless the caller opts into the lossy cast explicitly."""
    import pytest

    tree = {"w": jax.random.normal(rng, (4, 5), jnp.float32)}
    p = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(p, tree)
    like = {"w": jnp.zeros((4, 5), jnp.float16)}
    with pytest.raises(ValueError, match="dtype mismatch"):
        load_checkpoint(p, like)
    out = load_checkpoint(p, like, cast=True)
    assert out["w"].dtype == np.float16
    np.testing.assert_allclose(
        np.asarray(out["w"], np.float32),
        np.asarray(tree["w"], np.float32).astype(np.float16).astype(
            np.float32))


def test_leaf_count_and_shape_mismatch_raise(tmp_path):
    import pytest

    p = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(p, {"a": np.ones((3,), np.float32)})
    with pytest.raises(ValueError, match="leaves"):
        load_checkpoint(p, {"a": np.ones((3,), np.float32),
                            "b": np.ones((2,), np.float32)})
    with pytest.raises(ValueError, match="shape mismatch"):
        load_checkpoint(p, {"a": np.ones((4,), np.float32)})
