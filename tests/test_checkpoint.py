import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path, rng):
    tree = {
        "params": {"w": jax.random.normal(rng, (4, 5)),
                   "b": jnp.zeros((5,), jnp.bfloat16)},
        "step": 7,
        "lr": 1e-4,
    }
    p = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(p, tree)
    like = jax.tree.map(lambda x: x, tree)
    out = load_checkpoint(p, like)
    np.testing.assert_allclose(np.asarray(out["params"]["w"], np.float32),
                               np.asarray(tree["params"]["w"], np.float32))
    assert out["step"] == 7
    assert out["params"]["b"].dtype == jnp.bfloat16
