"""Recurrent-layer correctness: chunked/parallel training forms vs the exact
per-step decode recurrences (the decode step IS the oracle)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import xlstm as X


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mlstm_chunked_vs_step(chunk, rng):
    B, S, H, dh = 2, 64, 2, 16
    q = jax.random.normal(rng, (B, S, H, dh)) / 4
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, dh)) / 4
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, H, dh))
    i_raw = jax.random.normal(jax.random.fold_in(rng, 3), (B, S, H))
    f_raw = jax.random.normal(jax.random.fold_in(rng, 4), (B, S, H)) + 2

    h_chunk, state_c = X.mlstm_cell_chunked(q, k, v, i_raw, f_raw, chunk=chunk)

    state = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
             jnp.full((B, H), X.NEG))
    outs = []
    for t in range(S):
        h, state = X.mlstm_cell_step(q[:, t], k[:, t], v[:, t],
                                     i_raw[:, t], f_raw[:, t], state)
        outs.append(h)
    h_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_step),
                               rtol=2e-4, atol=2e-4)
    # final states agree too
    for a, b in zip(state_c, state):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_mamba_forward_vs_decode(rng):
    cfg = dataclasses.replace(get_config("jamba-1.5-large-398b").reduced(),
                              dtype="float32")
    params = L.init_mamba(cfg, rng)
    B, S = 2, 24
    x = jax.random.normal(jax.random.fold_in(rng, 9), (B, S, cfg.d_model)) / 2

    full = L.mamba(cfg, params, x, chunk=8)

    di = cfg.mamba_expand * cfg.d_model
    conv = jnp.zeros((B, cfg.conv_kernel - 1, di))
    ssm = jnp.zeros((B, di, cfg.d_state))
    outs = []
    for t in range(S):
        o, conv, ssm = L.mamba_decode(cfg, params, x[:, t], conv, ssm)
        outs.append(o)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-3, atol=2e-3)


def test_slstm_forward_vs_decode(rng):
    cfg = dataclasses.replace(get_config("xlstm-125m").reduced(), dtype="float32")
    params = X.init_slstm(cfg, rng)
    B, S = 2, 16
    x = jax.random.normal(rng, (B, S, cfg.d_model)) / 2

    full = X.slstm(cfg, params, x)

    state = X.init_slstm_state(cfg, B)
    outs = []
    for t in range(S):
        o, state = X.slstm_decode(cfg, params, x[:, t], state)
        outs.append(o)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunk_invariance(rng):
    """Chunk size must not change the result (associative-scan correctness)."""
    cfg = dataclasses.replace(get_config("jamba-1.5-large-398b").reduced(),
                              dtype="float32")
    params = L.init_mamba(cfg, rng)
    x = jax.random.normal(rng, (1, 32, cfg.d_model)) / 2
    a = L.mamba(cfg, params, x, chunk=4)
    b = L.mamba(cfg, params, x, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)
