"""Weighting functions (§IV-D/E): endpoint, monotonicity and normalization
properties — hypothesis property tests on the system's invariants."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.functions import (adaptive_learning_rates, round_weight_fn,
                                  staleness_fn, supervised_weight)


def test_supervised_weight_endpoints():
    C, M = 0.6, 10
    beta = 1.0 / (C * M + 1)
    assert abs(supervised_weight(0, C=C, M=M) - 0.5) < 1e-6
    assert abs(supervised_weight(10_000, C=C, M=M) - beta) < 1e-6
    assert supervised_weight(5, C=C, M=M, mode="fixed_alpha") == 0.5
    assert supervised_weight(5, C=C, M=M, mode="fixed_beta") == beta


@given(r=st.integers(min_value=0, max_value=500))
@settings(max_examples=50, deadline=None)
def test_supervised_weight_bounds_and_monotone(r):
    C, M = 0.6, 10
    w1 = supervised_weight(r, C=C, M=M)
    w2 = supervised_weight(r + 1, C=C, M=M)
    assert 0 < w1 < 1
    assert w2 <= w1 + 1e-12


@pytest.mark.parametrize("name", ["constant", "polynomial", "hinge",
                                  "exponential"])
def test_staleness_fn_properties(name):
    g = staleness_fn(name)
    assert abs(g(0) - 1.0) < 1e-9
    vals = [g(s) for s in range(8)]
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-12          # monotone non-increasing
        assert b > 0


@pytest.mark.parametrize("b", [1, 2, 5])
def test_hinge_staleness_continuous_at_b(b):
    """FedAsync-style hinge: flat at 1 until s = b, then 1/(a(s-b)+1) —
    continuous at the hinge point for any b. (The former 1/(a(s+b)+1)
    form jumped from 1 to 1/(2ab+1) at s = b whenever b > 0.)"""
    a = 0.7
    g = staleness_fn("hinge", a=a, b=b)
    assert g(b) == 1.0
    eps = 1e-9
    assert abs(g(b + eps) - 1.0) < 1e-6          # continuity at s = b
    # decay restarts AT the hinge: g(b + d) depends on d only, not on b
    for d in (1, 2, 3):
        assert abs(g(b + d) - 1.0 / (a * d + 1.0)) < 1e-12
    # monotone decreasing past the hinge, flat before it
    assert g(b - 1) == 1.0
    assert g(b + 2) < g(b + 1) < 1.0


def test_hinge_staleness_default_b0_unchanged():
    """b = 0 (the default) was never affected by the s+b bug."""
    g = staleness_fn("hinge")
    assert g(0) == 1.0
    for s in (1, 2, 3):
        assert abs(g(s) - 1.0 / (s + 1.0)) < 1e-12


@pytest.mark.parametrize("name", ["constant", "logarithmic", "polynomial",
                                  "exponential_smoothing", "exponential"])
def test_round_weight_nonneg_monotone(name):
    h = round_weight_fn(name)
    vals = [h(r) for r in range(10)]
    assert all(v >= 0 for v in vals)
    if name != "constant":
        assert vals[-1] >= vals[0]


@settings(max_examples=30, deadline=None)
@given(
    R=st.integers(min_value=1, max_value=20),
    M=st.integers(min_value=2, max_value=10),
    seed=st.integers(min_value=0, max_value=100),
)
def test_adaptive_lr_properties(R, M, seed):
    rng = np.random.default_rng(seed)
    part = (rng.random((R, M)) < 0.5).astype(float)
    lr = adaptive_learning_rates(part, base_lr=1e-4,
                                 round_weight="exponential_smoothing")
    assert lr.shape == (M,)
    assert np.all(lr >= 0.2e-4 - 1e-12) and np.all(lr <= 5e-4 + 1e-12)
    # a client that participates strictly more than another gets a lower lr
    part = np.zeros((4, 2))
    part[:, 0] = 1
    part[0, 1] = 1
    lr = adaptive_learning_rates(part, base_lr=1e-4, round_weight="constant")
    assert lr[0] < lr[1]
