"""Weighting functions (§IV-D/E): endpoint, monotonicity and normalization
properties — hypothesis property tests on the system's invariants."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.functions import (adaptive_learning_rates, round_weight_fn,
                                  staleness_fn, supervised_weight)


def test_supervised_weight_endpoints():
    C, M = 0.6, 10
    beta = 1.0 / (C * M + 1)
    assert abs(supervised_weight(0, C=C, M=M) - 0.5) < 1e-6
    assert abs(supervised_weight(10_000, C=C, M=M) - beta) < 1e-6
    assert supervised_weight(5, C=C, M=M, mode="fixed_alpha") == 0.5
    assert supervised_weight(5, C=C, M=M, mode="fixed_beta") == beta


@given(r=st.integers(min_value=0, max_value=500))
@settings(max_examples=50, deadline=None)
def test_supervised_weight_bounds_and_monotone(r):
    C, M = 0.6, 10
    w1 = supervised_weight(r, C=C, M=M)
    w2 = supervised_weight(r + 1, C=C, M=M)
    assert 0 < w1 < 1
    assert w2 <= w1 + 1e-12


@pytest.mark.parametrize("name", ["constant", "polynomial", "hinge",
                                  "exponential"])
def test_staleness_fn_properties(name):
    g = staleness_fn(name)
    assert abs(g(0) - 1.0) < 1e-9
    vals = [g(s) for s in range(8)]
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-12          # monotone non-increasing
        assert b > 0


@pytest.mark.parametrize("name", ["constant", "logarithmic", "polynomial",
                                  "exponential_smoothing", "exponential"])
def test_round_weight_nonneg_monotone(name):
    h = round_weight_fn(name)
    vals = [h(r) for r in range(10)]
    assert all(v >= 0 for v in vals)
    if name != "constant":
        assert vals[-1] >= vals[0]


@settings(max_examples=30, deadline=None)
@given(
    R=st.integers(min_value=1, max_value=20),
    M=st.integers(min_value=2, max_value=10),
    seed=st.integers(min_value=0, max_value=100),
)
def test_adaptive_lr_properties(R, M, seed):
    rng = np.random.default_rng(seed)
    part = (rng.random((R, M)) < 0.5).astype(float)
    lr = adaptive_learning_rates(part, base_lr=1e-4,
                                 round_weight="exponential_smoothing")
    assert lr.shape == (M,)
    assert np.all(lr >= 0.2e-4 - 1e-12) and np.all(lr <= 5e-4 + 1e-12)
    # a client that participates strictly more than another gets a lower lr
    part = np.zeros((4, 2))
    part[:, 0] = 1
    part[0, 1] = 1
    lr = adaptive_learning_rates(part, base_lr=1e-4, round_weight="constant")
    assert lr[0] < lr[1]
