"""Sharding rule properties: pjit argument specs must always divide dims."""
import jax
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.distributed.sharding import _fit, cache_specs, param_specs
from repro.models import lm
from tests.test_configs import ASSIGNED

AXES = {"pod": 2, "data": 16, "model": 16}


@settings(max_examples=100, deadline=None)
@given(
    dims=st.lists(st.integers(min_value=1, max_value=4096), min_size=1,
                  max_size=4),
    spec=st.lists(st.sampled_from([None, "data", "model", ("pod", "data"),
                                   ("data", "model")]), min_size=1, max_size=4),
)
def test_fit_always_divides(dims, spec):
    spec = spec[:len(dims)] + [None] * (len(dims) - len(spec))
    fitted = _fit(tuple(spec), tuple(dims), AXES)
    for d, s in zip(dims, fitted):
        if s is None:
            continue
        axes = (s,) if isinstance(s, str) else s
        prod = 1
        for a in axes:
            prod *= AXES[a]
        assert d % prod == 0, (d, s)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divide(arch):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes, AXES, fsdp=True)

    def check(path, leaf, spec):
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            prod = 1
            for a in axes:
                prod *= AXES[a]
            assert dim % prod == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, shapes, specs)


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "deepseek-v2-236b",
                                  "whisper-medium", "xlstm-125m"])
@pytest.mark.parametrize("batch_size,cache_len", [(128, 32768), (1, 8192)])
def test_cache_specs_divide(arch, batch_size, cache_len):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: lm.init_cache(cfg, batch_size, cache_len))
    specs = cache_specs(cfg, shapes, AXES, batch_size=batch_size)

    def check(path, leaf, spec):
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            prod = 1
            for a in axes:
                prod *= AXES[a]
            assert dim % prod == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, shapes, specs)
