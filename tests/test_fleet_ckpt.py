"""Crash-consistent fleet checkpointing (core.fleet_ckpt).

Three layers:

* **encoding** — the msgpack value codec round-trips numpy/JAX arrays
  (dtype-exact), int-keyed dicts, and the 128-bit PCG64 state words that
  make numpy Generator snapshots restore bit-exactly;
* **torn-write recovery** — an interrupted or bit-rotted newest
  checkpoint is invisible to ``find_restorable``: restore falls back to
  the previous good checkpoint instead of loading garbage;
* **bit-exact resume** — ``train(2k)`` and ``train(k) -> fresh trainer
  -> restore() -> train(k)`` produce identical global models, ACO, fault
  traces, fleet health and metrics across engines x {resident, paged} x
  {csr, csr_q} x chunked layouts, under REFERENCE_CHURN plus corrupted
  uploads.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import (REFERENCE_CHURN, FedS3AConfig, FedS3ATrainer)
from repro.core import fleet_ckpt
from repro.core.sparse_comm import flatten_tree
from repro.data import make_dataset

TEST_CNN = CNNConfig(name="feds3a-cnn-ckpt", conv_filters=(8, 8), hidden=16)
CHURN = dataclasses.replace(REFERENCE_CHURN, corrupt_prob=0.15)


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


# -- value codec ------------------------------------------------------------
def test_pack_roundtrips_arrays_bigints_and_int_keys():
    rng = np.random.default_rng(7)
    obj = {
        "arr_f32": rng.standard_normal((3, 5)).astype(np.float32),
        "arr_i8": np.arange(-4, 4, dtype=np.int8),
        "bool_mask": np.array([True, False, True]),
        "rng_state": rng.bit_generator.state,      # 128-bit state words
        3: {"nested": [1, 2.5, None, "s"], -2: (1, 2)},
        "big": (1 << 100) + 17,
        "neg_big": -(1 << 90),
    }
    out = fleet_ckpt.unpack(fleet_ckpt.pack(obj))
    assert np.array_equal(out["arr_f32"], obj["arr_f32"])
    assert out["arr_f32"].dtype == np.float32
    assert np.array_equal(out["arr_i8"], obj["arr_i8"])
    assert out["arr_i8"].dtype == np.int8
    assert out["bool_mask"].dtype == bool
    assert out["rng_state"] == obj["rng_state"]
    assert out[3]["nested"] == [1, 2.5, None, "s"]
    assert out[3][-2] == [1, 2]                    # tuples land as lists
    assert out["big"] == obj["big"] and out["neg_big"] == obj["neg_big"]
    # the restored state must actually drive a Generator identically
    g1, g2 = np.random.default_rng(7), np.random.default_rng(0)
    g1.random(5)
    g2.bit_generator.state = fleet_ckpt.unpack(
        fleet_ckpt.pack(g1.bit_generator.state))
    assert np.array_equal(g1.random(8), g2.random(8))


# -- atomic write / torn-write recovery -------------------------------------
def test_find_restorable_skips_torn_and_corrupt(tmp_path):
    root = str(tmp_path)
    a = fleet_ckpt.write_checkpoint(root, 5, {"s": {"x": 1}}, {"fp": 1})
    b = fleet_ckpt.write_checkpoint(root, 10, {"s": {"x": 2}}, {"fp": 1})
    path, man = fleet_ckpt.find_restorable(root)
    assert path == b and man["round"] == 10

    # bit-rot in a section: digest mismatch -> fall back to round 5
    sec = os.path.join(b, "s.msgpack")
    blob = bytearray(open(sec, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(sec, "wb").write(bytes(blob))
    path, man = fleet_ckpt.find_restorable(root)
    assert path == a and man["round"] == 5
    assert fleet_ckpt.read_section(a, "s") == {"x": 1}

    # a write that died before its MANIFEST landed is invisible
    c = os.path.join(root, "ckpt-00000015")
    os.makedirs(c)
    with open(os.path.join(c, "s.msgpack"), "wb") as f:
        f.write(fleet_ckpt.pack({"x": 3}))
    path, _ = fleet_ckpt.find_restorable(root)
    assert path == a

    # truncated MANIFEST (torn rename target) is equally invisible
    with open(os.path.join(c, fleet_ckpt.MANIFEST_NAME), "wb") as f:
        f.write(b"\x82\xa6")
    path, _ = fleet_ckpt.find_restorable(root)
    assert path == a


def test_retention_keeps_last_two(tmp_path):
    root = str(tmp_path)
    for r in (2, 4, 6, 8):
        fleet_ckpt.write_checkpoint(root, r, {"s": {"r": r}}, {})
    assert [r for r, _ in fleet_ckpt.checkpoint_dirs(root)] == [6, 8]


# -- bit-exact trainer resume ----------------------------------------------
_FULL_MATRIX = [
    dict(engine="sequential", error_feedback=True),
    dict(engine="batched", error_feedback=True),
    dict(engine="sharded", error_feedback=True),
    dict(engine="batched", error_feedback=True, wire_format="csr_q",
         client_store="paged"),
    dict(engine="sharded", error_feedback=True, client_store="paged"),
    dict(engine="batched", error_feedback=True, chunk_size=400),
]
# Each cell compiles three trainers, so the full engine x store x wire
# sweep costs several minutes of pure recompilation. The default (tier-1)
# run pins two representative cells — the batched resident EF path and the
# quantized paged path — and CI's kill-resume job sets CKPT_FULL_MATRIX=1
# to sweep all six.
CELLS = _FULL_MATRIX if os.environ.get("CKPT_FULL_MATRIX") \
    else [_FULL_MATRIX[1], _FULL_MATRIX[3]]


def _mk(data, ckpt_dir, **kw):
    cfg = FedS3AConfig(rounds=50, cnn=TEST_CNN, seed=0, traffic=CHURN,
                       round_deadline=700.0, quorum_floor=1,
                       checkpoint_dir=ckpt_dir, checkpoint_every=2, **kw)
    return FedS3ATrainer(data, cfg)


def _flat(tr):
    return np.asarray(tr._global_flat if tr._gp_tree is None
                      else flatten_tree(tr.global_params))


def _trace(tr):
    return [(l.participants, l.forced, l.lost, l.corrupted, l.departed,
             l.rejoined, l.resynced, l.quorum, l.target_k, l.degraded,
             l.crashes, round(l.time, 9)) for l in tr.logs]


@pytest.mark.parametrize("cell", CELLS,
                         ids=["-".join(f"{v}" for v in c.values())
                              for c in CELLS])
def test_resume_is_bit_exact(data, tmp_path, cell):
    """train(6) == train(3) -> fresh trainer -> restore -> train(3), to the
    bit, for every state the round touches — under churn, losses AND
    quarantined uploads."""
    ta = _mk(data, str(tmp_path / "a"), **cell)
    ra = ta.train(6)
    tb = _mk(data, str(tmp_path / "b"),
             **{**cell, "paged_dir": str(tmp_path / "pg_b")
                if cell.get("client_store") == "paged" else None})
    tb.train(3)
    tc = _mk(data, str(tmp_path / "b"),
             **{**cell, "paged_dir": str(tmp_path / "pg_c")
                if cell.get("client_store") == "paged" else None})
    assert tc.restore() == 3
    rc = tc.train(3)
    assert np.array_equal(_flat(ta), _flat(tc))
    assert ra["aco"] == rc["aco"]
    assert ra["fleet"] == rc["fleet"]
    assert ra["metrics"] == rc["metrics"]
    assert _trace(ta) == _trace(tc)
    # base-store state converged too: versions, detached mask, ring
    assert np.array_equal(ta.store.client_version, tc.store.client_version)
    assert np.array_equal(ta.store.detached, tc.store.detached)
    assert np.array_equal(np.asarray(ta.store.ring),
                          np.asarray(tc.store.ring))


def test_restore_falls_back_past_torn_trainer_checkpoint(data, tmp_path):
    """SIGKILL-shaped damage on the NEWEST trainer checkpoint (truncated
    section) must restore the previous one, and training onward from it
    still matches the uninterrupted run."""
    root = str(tmp_path / "ck")
    ta = _mk(data, str(tmp_path / "ref"), engine="batched",
             error_feedback=True)
    ra = ta.train(6)
    tb = _mk(data, root, engine="batched", error_feedback=True)
    tb.train(4)            # checkpoints at rounds 2 and 4
    newest = fleet_ckpt.checkpoint_dirs(root)[-1][1]
    sec = os.path.join(newest, "trainer.msgpack")
    blob = open(sec, "rb").read()
    open(sec, "wb").write(blob[:len(blob) // 2])
    tc = _mk(data, root, engine="batched", error_feedback=True)
    assert tc.restore() == 2
    rc = tc.train(4)
    assert np.array_equal(_flat(ta), _flat(tc))
    assert ra["fleet"] == rc["fleet"]
    assert _trace(ta) == _trace(tc)


def test_restore_rejects_mismatched_fingerprint(data, tmp_path):
    root = str(tmp_path / "ck")
    ta = _mk(data, root, engine="batched", error_feedback=True)
    ta.train(2)
    tc = _mk(data, root, engine="batched", error_feedback=False)
    with pytest.raises(ValueError, match="fingerprint"):
        tc.restore()
    empty = str(tmp_path / "nothing")
    with pytest.raises(FileNotFoundError):
        ta.restore(empty)


def test_checkpoint_requires_versioned_store(data, tmp_path):
    with pytest.raises(ValueError, match="versioned"):
        FedS3ATrainer(data, FedS3AConfig(
            cnn=TEST_CNN, base_store="dense",
            checkpoint_dir=str(tmp_path)))
