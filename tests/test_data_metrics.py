"""Synthetic CIC-IDS data: Table III fidelity, entropies, metrics."""
import numpy as np

from repro.core.metrics import weighted_metrics
from repro.data import (BALANCED_SCENARIO, BASIC_SCENARIO, make_dataset,
                        shannon_entropy)

# Table III's printed entropy column (basic scenario)
PAPER_ENTROPY_BASIC = [0.5981, 0.1794, 0.4880, 0.1423, 0.4729,
                       0.5054, 0.4043, 0.0, 0.6062, 0.3681]


def test_entropy_matches_paper_table():
    for counts, expect in zip(BASIC_SCENARIO, PAPER_ENTROPY_BASIC):
        assert abs(shannon_entropy(counts) - expect) < 0.02


def test_balanced_entropies_equal():
    es = [shannon_entropy(c) for c in BALANCED_SCENARIO]
    assert np.ptp(es) < 0.001
    assert abs(es[0] - 0.6553) < 0.01


def test_dataset_counts_scale():
    data = make_dataset("basic", scale=0.01)
    assert len(data["clients"]) == 10
    for i, c in enumerate(data["clients"]):
        assert len(c["x"]) == data["counts"][i].sum()
        expect = (BASIC_SCENARIO[i] * 0.01).astype(int).sum()
        assert len(c["x"]) == expect
    assert data["server"]["x"].shape[1] == 78


def test_server_fraction():
    data = make_dataset("basic", scale=0.02, server_frac=0.05)
    total = sum(len(c["x"]) for c in data["clients"])
    assert 0.03 < len(data["server"]["x"]) / total < 0.09


def test_client_side_is_noniid_in_basic():
    data = make_dataset("basic", scale=0.01)
    assert data["entropy"][7] == 0.0          # client 7: benign only
    assert data["entropy"][0] > 0.5


def test_weighted_metrics_perfect():
    y = np.array([0, 1, 2, 2, 1])
    m = weighted_metrics(y, y, 3)
    assert m["accuracy"] == 1.0
    assert m["f1"] == 1.0
    assert m["fpr"] == 0.0


def test_weighted_metrics_known_case():
    y_true = np.array([0, 0, 1, 1])
    y_pred = np.array([0, 1, 1, 1])
    m = weighted_metrics(y_true, y_pred, 2)
    assert abs(m["accuracy"] - 0.75) < 1e-9
    # class 0: P=1, R=.5; class 1: P=2/3, R=1 -> weighted P = 5/6
    assert abs(m["precision"] - (0.5 * 1.0 + 0.5 * 2 / 3)) < 1e-9
    assert abs(m["recall"] - 0.75) < 1e-9
