"""Wire-integrity gauntlet + quarantine (corrupt-fated uploads).

Pins, per ISSUE 10:

* every malformed-payload class in :data:`MALFORM_KINDS` — bad row_ptr,
  out-of-bounds index, NaN/inf value or scale, wrong arity, truncated
  buffer, wrong dtype — raises :class:`WireIntegrityError` under BOTH
  CSR wire formats, from a nominal payload that validates cleanly;
* rejection mutates nothing: not the byte ledgers, not the EF residuals,
  not the global model (quarantine == the lost-upload no-delivery path);
* the quarantine trace is engine-independent (it derives purely from the
  scheduler's fault stream), and quarantined uploads book ZERO bytes —
  the whole ledger stays an exact arithmetic identity of the trace.
"""
import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import (MALFORM_KINDS, REFERENCE_CHURN, FedS3AConfig,
                        FedS3ATrainer, WireIntegrityError)
from repro.core.sparse_comm import SparseComm
from repro.data import make_dataset

TEST_CNN = CNNConfig(name="feds3a-cnn-wire", conv_filters=(8, 8), hidden=16)
CHURN = dataclasses.replace(REFERENCE_CHURN, corrupt_prob=0.2)


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


def _nominal(fmt, seed=0):
    """A real encoded payload's delivery stats for ``fmt``."""
    comm = SparseComm("p0.2", use_kernel=False, wire_format=fmt)
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    base = {"w": jax.random.normal(k1, (96,)), "b": jnp.zeros((32,))}
    new = {"w": base["w"] + 0.1 * jax.random.normal(k2, (96,)),
           "b": base["b"] + 0.05}
    _, stats = comm.encode(new, base, deliver=False)
    return comm, stats


@pytest.mark.parametrize("fmt", ["csr", "csr_q"])
def test_nominal_payload_validates(fmt):
    comm, stats = _nominal(fmt)
    assert comm.validate_payload(stats) is stats


@pytest.mark.parametrize("fmt", ["csr", "csr_q"])
@pytest.mark.parametrize("kind", MALFORM_KINDS)
def test_every_malformation_class_is_rejected(fmt, kind):
    comm, stats = _nominal(fmt)
    before = comm.ledger_state()
    bad = comm.malform_stats(stats, kind)
    with pytest.raises(WireIntegrityError):
        comm.validate_payload(bad)
    # rejection booked nothing and malform copied rather than mutated
    assert comm.ledger_state() == before
    comm.validate_payload(stats)


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(min_value=1, max_value=5),
       cap=st.integers(min_value=1, max_value=9),
       kind=st.sampled_from(MALFORM_KINDS),
       seed=st.integers(min_value=0, max_value=3))
def test_malformation_rejected_at_any_geometry(rows, cap, kind, seed):
    """Synthetic payloads of arbitrary row/capacity geometry: the clean
    one validates, every malformed variant is caught."""
    comm = SparseComm("p0.2", use_kernel=False, wire_format="csr")
    rng = np.random.default_rng(seed)
    n = cap * 7 + 3
    stored = rng.integers(0, cap + 1, rows)
    stats = {"nnz": stored, "total": n, "rows": rows,
             "values": rng.standard_normal((rows, cap)).astype(np.float32),
             "indices": rng.integers(0, n, (rows, cap)).astype(np.int32)}
    comm.validate_payload(stats)
    with pytest.raises(WireIntegrityError):
        comm.validate_payload(comm.malform_stats(stats, kind))


def test_quarantine_mutates_no_trainer_state(data):
    """A boundary full of corrupt uploads leaves EF residuals, ledgers and
    the global model untouched (and raises on none of them)."""
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=8, cnn=TEST_CNN, engine="batched", error_feedback=True,
        traffic=CHURN, round_deadline=700.0))
    tr.train(2)
    flat = np.asarray(tr._global_flat).copy()
    res_v = np.asarray(tr._res_vals).copy() if hasattr(tr, "_res_vals") \
        else np.stack([np.asarray(r) for r in tr._residual_rows])
    ledger = tr.comm.ledger_state()
    tr._quarantine_uploads(SimpleNamespace(corrupted=[0, 3, 7, 11, 19]))
    assert np.array_equal(np.asarray(tr._global_flat), flat)
    got = np.asarray(tr._res_vals) if hasattr(tr, "_res_vals") \
        else np.stack([np.asarray(r) for r in tr._residual_rows])
    assert np.array_equal(got, res_v)
    assert tr.comm.ledger_state() == ledger


@pytest.mark.parametrize("fmt", ["csr", "csr_q"])
def test_quarantine_trace_is_engine_independent(data, fmt):
    """The corrupt-fate stream derives purely from the scheduler's traffic
    RNG, so every engine quarantines the identical clients at the
    identical rounds."""
    traces = []
    for engine in ("sequential", "batched", "sharded"):
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=6, cnn=TEST_CNN, engine=engine, wire_format=fmt,
            error_feedback=True, traffic=CHURN, round_deadline=700.0))
        tr.train()
        traces.append([(l.participants, l.lost, l.corrupted,
                        round(l.time, 9)) for l in tr.logs])
    assert traces[0] == traces[1] == traces[2]
    assert any(l for _, _, l, _ in traces[0]), \
        "profile produced no quarantined uploads; weak test"


def test_quarantined_uploads_book_zero_bytes(data):
    """With sparsification disabled every message is exactly n*4 bytes, so
    the ledger is an exact arithmetic identity of the fault trace: one
    upload per DELIVERED participant (lost AND quarantined uploads
    absent), one dense broadcast per round with targets (quarantined
    clients DO rebase — they restart from the new global model like lost
    ones), one dense unicast per resync."""
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=15, cnn=TEST_CNN, engine="batched", sparse_comm=False,
        traffic=CHURN, round_deadline=700.0))
    tr.train()
    n = int(tr._global_flat.shape[0])
    uploads = rounds_with_targets = resyncs = quarantined = 0
    for l in tr.logs:
        uploads += len(l.participants)
        resyncs += len(l.resynced)
        quarantined += len(l.corrupted)
        online_parts = set(l.participants) - (set(l.departed)
                                              - set(l.rejoined))
        chain = set(l.rejoined) - set(l.resynced)
        if online_parts | set(l.forced) | set(l.lost) | set(l.corrupted) \
                | chain:
            rounds_with_targets += 1
    assert quarantined > 0, "profile produced no quarantines; weak test"
    expected = 4 * n * (uploads + rounds_with_targets + resyncs)
    assert tr.comm.payload_bytes == expected
    assert tr.comm.messages == uploads + rounds_with_targets + resyncs
    from repro.core.metrics import fleet_health
    assert fleet_health(tr.logs)["quarantined"] == quarantined
