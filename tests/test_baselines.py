"""Baseline trainers (§V-F1): per-epoch RNG derivation, the FedAsync
staleness guard's forced-sync path (and its truthful wire accounting), and
the empty-ledger ACO convention shared with SparseComm."""
import jax
import numpy as np
import pytest

from repro.core import FedS3AConfig
from repro.core.baselines import FedAsyncSSL, FedAvgSSL
from repro.core.metrics import weighted_metrics
from repro.core.sparse_comm import SparseComm
from repro.data import make_dataset


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


def _model_floats(tr):
    return sum(l.size for l in jax.tree.leaves(tr.global_params))


def test_each_epoch_gets_its_own_key(data):
    """epochs > 1 must fold the epoch index into the client key — one key
    replayed across epochs repeats the same batch shuffle and dropout mask
    every epoch (the bug FedS3A's engines fixed; this pins the baselines'
    shared `_train_client` to the same derivation)."""
    tr = FedAvgSSL(data, FedS3AConfig(rounds=1, seed=0, epochs=3))
    seen = []
    inner = tr.client_epoch

    def spy(params, opt, x, lr, key):
        seen.append(np.asarray(key))
        return inner(params, opt, x, lr, key)

    tr.client_epoch = spy
    tr._train_client(0, tr.global_params, tr.cfg.lr)
    assert len(seen) == 3
    # epoch 0 keeps the raw split (single-epoch runs bit-identical to the
    # old behaviour); later epochs derive fold_in(key, e) — all distinct
    assert np.array_equal(seen[1], np.asarray(
        jax.random.fold_in(seen[0], 1)))
    assert np.array_equal(seen[2], np.asarray(
        jax.random.fold_in(seen[0], 2)))
    keys = {tuple(k.tolist()) for k in seen}
    assert len(keys) == 3


def test_fedasync_straggler_forced_sync_accounting(data):
    """A straggler whose staleness exceeds max_stale is force-synced: it
    gets the fresh model (ONE downlink message on the wire), is requeued,
    and the event does NOT consume a round or advance the global version.
    The old path trained it anyway, silently dropped the upload, yet booked
    a full round-trip and burned the round."""
    rounds = 12
    tr = FedAsyncSSL(data, FedS3AConfig(rounds=rounds, seed=0), max_stale=2)
    # two-speed fleet: client 0 laps the fleet (one arrival per tick) so
    # by the stragglers' first arrival at t=5 the global version is
    # already 4 versions ahead — past max_stale=2
    tr.latencies = [1.0] + [5.0] * (tr.M - 1)
    res = tr.train()
    assert tr.forced_syncs > 0
    assert res["forced_syncs"] == tr.forced_syncs
    assert res["rounds"] == rounds
    # every aggregated arrival books an up+down round-trip; every forced
    # sync books exactly the one model that crossed the wire
    n = _model_floats(tr)
    assert tr.comm_bytes == (2 * rounds + tr.forced_syncs) * n * 4


def test_fedasync_no_stale_upload_is_aggregated(data):
    """With the guard at the arrival point, every blended upload has
    staleness <= max_stale by construction: a max_stale=0 run still
    completes its rounds (stragglers resync instead of wedging or being
    silently dropped)."""
    tr = FedAsyncSSL(data, FedS3AConfig(rounds=4, seed=0), max_stale=0)
    tr.latencies = [1.0] + [2.5] * (tr.M - 1)
    res = tr.train()
    assert res["rounds"] == 4
    assert tr.forced_syncs > 0


def test_empty_ledger_aco_matches_sparse_comm(data):
    """Before anything crosses the wire both ledgers must agree: ACO 0.0
    (the `_Base` property used to read 1.0 while SparseComm read 0.0,
    so 'no traffic yet' flipped meaning between trainers)."""
    tr = FedAvgSSL(data, FedS3AConfig(rounds=1, seed=0))
    comm = SparseComm(threshold=0.005)
    assert tr.aco == comm.aco == 0.0


def test_weighted_metrics_keys_unchanged():
    y = np.array([0, 1, 2, 2, 1, 0])
    p = np.array([0, 1, 1, 2, 1, 0])
    m = weighted_metrics(y, p, 3)
    assert set(m) == {"accuracy", "precision", "recall", "f1", "fpr"}
    assert m["accuracy"] == pytest.approx(5 / 6)
