"""Per-architecture smoke tests: REDUCED variants (2 layers, d_model<=512,
<=4 experts) run one forward + one train step + decode + prefill on CPU,
asserting output shapes and no NaNs — deliverable (f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.optimizer import adam_init
from repro.training.steps import make_serve_step, make_train_step
from tests.test_configs import ASSIGNED


def _batch(cfg, rng, B=2, S=32):
    b = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(
            rng, (B, cfg.num_encoder_positions, cfg.d_model))
    if cfg.num_vision_patches:
        b["patches"] = jax.random.normal(
            rng, (B, cfg.num_vision_patches, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits, aux, _ = jax.jit(
        lambda p, b: lm.forward(cfg, p, b))(params, batch)
    S_total = 32 + (cfg.num_vision_patches or 0)
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    step = jax.jit(make_train_step(cfg, num_microbatches=2))
    p2, o2, loss = step(params, adam_init(params), batch)
    assert jnp.isfinite(loss)
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)).sum()), p2, params))
    assert moved > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_and_prefill(arch, rng):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, rng)
    B, CL = 2, 16
    cache = lm.init_cache(cfg, B, CL)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((B,), jnp.int32)
    for i in range(3):
        tok, logits, cache = serve(params, cache, tok, jnp.int32(i))
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    batch = _batch(cfg, rng, B=B, S=8)
    last, cache2 = jax.jit(lambda p, b: lm.prefill(cfg, p, b, CL))(params, batch)
    assert last.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(last).any())


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "granite-8b"])
def test_ring_decode(arch, rng):
    """Sliding-window ring-buffer decode (long_500k carve-in)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, rng)
    B, W = 1, 8
    cache = lm.init_cache(cfg, B, W)
    serve = jax.jit(make_serve_step(cfg, ring=True))
    tok = jnp.zeros((B,), jnp.int32)
    for i in range(W + 4):   # wrap the ring
        tok, logits, cache = serve(params, cache, tok, jnp.int32(i))
    assert not bool(jnp.isnan(logits).any())
