"""FedS3A-on-the-mesh (core/distributed_fl.py): the single-step federated
round over model-zoo architectures."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.distributed_fl import make_fl_train_step
from repro.models import lm


def _setup(arch="qwen2-1.5b", M=4, LS=2, B=2, S=32):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (M, LS, B, S), 0, cfg.vocab_size)}
    return cfg, params, batch


def test_masked_client_contributes_nothing():
    cfg, params, batch = _setup()
    step = make_fl_train_step(cfg, num_clients=4, lr=1e-2, local_steps=2,
                              impl="ref", f_weight=0.0)
    sizes = jnp.ones((4,))
    stal = jnp.zeros((4,))
    m_all = jnp.array([1., 1., 1., 1.])
    m_drop = jnp.array([1., 1., 1., 0.])
    out_all, _ = jax.jit(step)(params, batch, m_all, stal, sizes)
    out_drop, _ = jax.jit(step)(params, batch, m_drop, stal, sizes)
    # dropping a client must change the aggregate
    diff = jax.tree.reduce(lambda a, b: a + b, jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32)).sum()),
        out_all, out_drop))
    assert diff > 0

    # and out_drop must equal aggregating only the first three clients
    batch3 = jax.tree.map(lambda t: t[:3], batch)
    step3 = make_fl_train_step(cfg, num_clients=3, lr=1e-2, local_steps=2,
                               impl="ref", f_weight=0.0)
    out3, _ = jax.jit(step3)(params, batch3, jnp.ones((3,)), stal[:3],
                             sizes[:3])
    for a, b in zip(jax.tree.leaves(out_drop), jax.tree.leaves(out3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_staleness_downweights():
    cfg, params, batch = _setup()
    step = make_fl_train_step(cfg, num_clients=4, lr=1e-2, local_steps=2,
                              impl="ref", f_weight=0.0)
    sizes = jnp.ones((4,))
    mask = jnp.ones((4,))
    fresh, _ = jax.jit(step)(params, batch, mask, jnp.zeros((4,)), sizes)
    stale, _ = jax.jit(step)(params, batch, mask,
                             jnp.array([0., 0., 0., 5.]), sizes)
    # both move params, results differ (client 3 decayed)
    d = jax.tree.reduce(lambda a, b: a + b, jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32)).sum()),
        fresh, stale))
    assert d > 0


def test_sparsified_round_still_descends():
    cfg, params, batch = _setup()
    from repro.training.steps import lm_loss
    mb = jax.tree.map(lambda t: t[0, 0], batch)
    step = make_fl_train_step(cfg, num_clients=4, lr=1e-2, local_steps=2,
                              keep_frac=0.25, impl="ref", f_weight=0.0)
    new, _ = jax.jit(step)(params, batch, jnp.ones((4,)), jnp.zeros((4,)),
                           jnp.ones((4,)))
    l0 = float(lm_loss(cfg, params, {"tokens": mb["tokens"]}, impl="ref"))
    l1 = float(lm_loss(cfg, new, {"tokens": mb["tokens"]}, impl="ref"))
    assert l1 < l0
