"""Pallas kernel sweeps: shapes/dtypes vs the ref.py pure-jnp oracles
(interpret mode on CPU) — deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R


@pytest.mark.parametrize("S,Hq,Hkv,hd,dtype", [
    (128, 4, 4, 32, jnp.float32),
    (256, 8, 2, 64, jnp.float32),
    (128, 4, 1, 64, jnp.bfloat16),
    (384, 6, 2, 128, jnp.float32),
])
@pytest.mark.parametrize("window", [None, 96])
def test_flash_attention_sweep(S, Hq, Hkv, hd, dtype, window, rng):
    B = 2
    q = jax.random.normal(rng, (B, S, Hq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, window=window)
    G = Hq // Hkv
    ref = R.flash_attention_ref(q, jnp.repeat(k, G, 2), jnp.repeat(v, G, 2),
                                window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("N,C", [(64, 9), (300, 9), (256, 100), (77, 17)])
@pytest.mark.parametrize("thr", [0.5, 0.95])
def test_masked_pseudo_ce_sweep(N, C, thr, rng):
    logits = jax.random.normal(rng, (N, C)) * 3
    loss, mask = ops.masked_pseudo_ce(logits, thr)
    rl, rm = R.masked_pseudo_ce_ref(logits, thr)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(rl), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rm))


def test_masked_pseudo_ce_grad(rng):
    logits = jax.random.normal(rng, (64, 9)) * 4
    g = jax.grad(lambda lg: ops.masked_pseudo_ce(lg, 0.8)[0].sum())(logits)
    # finite differences on a masked (confident) sample
    _, mask = R.masked_pseudo_ce_ref(logits, 0.8)
    idx = int(np.argmax(np.asarray(mask)))
    eps = 1e-3
    for j in (0, 3):
        lp = logits.at[idx, j].add(eps)
        lmn = logits.at[idx, j].add(-eps)
        fd = (R.masked_pseudo_ce_ref(lp, 0.8)[0].sum()
              - R.masked_pseudo_ce_ref(lmn, 0.8)[0].sum()) / (2 * eps)
        assert abs(float(fd) - float(g[idx, j])) < 1e-2


@pytest.mark.parametrize("n", [512, 2048, 1000, 4096 + 17])
@pytest.mark.parametrize("thr", [0.1, 1.0, 10.0])
def test_sparse_delta_sweep(n, thr, rng):
    x = jax.random.normal(rng, (n,))
    masked, nnz = ops.sparse_delta(x, thr)
    pad = (-n) % 512
    xr = jnp.concatenate([x, jnp.zeros(pad)]) if pad else x
    rmasked, rnnz = R.sparse_delta_ref(xr, thr)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(rmasked[:n]))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))


@pytest.mark.parametrize("K,n", [(1, 512), (4, 2048), (7, 1000)])
def test_sparse_delta_2d_sweep(K, n, rng):
    """2D grid (clients, N//512): per-client thresholds, one kernel call."""
    x = jax.random.normal(rng, (K, n))
    thr = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 1), (K,))) * 0.5
    masked, nnz = ops.sparse_delta_batch(x, thr)
    pad = (-n) % 512
    xr = jnp.concatenate([x, jnp.zeros((K, pad))], axis=1) if pad else x
    rmasked, rnnz = R.sparse_delta2d_ref(xr, thr)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(rmasked[:, :n]))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))


def test_sparse_delta_2d_matches_per_row_1d(rng):
    """Each row of the 2D kernel equals the 1D kernel on that row."""
    x = jax.random.normal(rng, (3, 1024))
    thr = jnp.asarray([0.2, 0.8, 1.5])
    masked2, nnz2 = ops.sparse_delta_batch(x, thr)
    for i in range(3):
        m1, n1 = ops.sparse_delta(x[i], float(thr[i]))
        np.testing.assert_allclose(np.asarray(masked2[i]), np.asarray(m1))
        np.testing.assert_array_equal(np.asarray(nnz2[i]), np.asarray(n1))


@pytest.mark.parametrize("K,n", [(3, 512), (10, 2048), (6, 1000)])
def test_staleness_agg_sweep(K, n, rng):
    d = jax.random.normal(rng, (K, n))
    w = jax.random.uniform(jax.random.fold_in(rng, 1), (K,))
    out = ops.staleness_agg(d, w)
    ref = R.staleness_agg_ref(d, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:n]),
                               rtol=1e-5, atol=1e-5)


def test_flash_matches_xla_flash(rng):
    """Pallas kernel vs the XLA nested-scan flash (structural twin)."""
    from repro.models.layers import flash_attention_xla
    B, S, H, hd = 1, 256, 4, 64
    q = jax.random.normal(rng, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    a = ops.flash_attention(q, k, v)
    b = flash_attention_xla(q, k, v, pos, pos, qblk=64, kblk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
