"""Batched round engine: parity with the sequential reference path, the
2D-grid sparse-delta kernel, and the sync-free deferred ACO accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import FedS3AConfig, FedS3ATrainer
from repro.core.sparse_comm import (SparseComm, flatten_tree,
                                    unflatten_stacked)
from repro.data import make_dataset

# reduced-width instance of the paper's CNN so the parity run is fast
TEST_CNN = CNNConfig(name="feds3a-cnn-test", conv_filters=(8, 8), hidden=16)


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


@pytest.fixture(scope="module")
def both_engines(data):
    out = {}
    for batched in (False, True):
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=4, seed=0, engine="batched" if batched else "sequential",
            cnn=TEST_CNN))
        res = tr.train()
        out[batched] = (tr, res)
    return out


def test_parity_metrics(both_engines):
    """Same seed -> identical final metrics from either engine."""
    (_, seq), (_, bat) = both_engines[False], both_engines[True]
    for k in seq["metrics"]:
        assert abs(seq["metrics"][k] - bat["metrics"][k]) < 1e-5, k


def test_parity_aco(both_engines):
    """ACO agrees between engines. The engines run identical math but not
    identical float reduction orders, so a few delta elements sitting
    exactly at the sampled quantile threshold can flip — that bounds the
    drift at ~1e-3 relative, far inside the paper-level signal (~0.49)."""
    (_, seq), (_, bat) = both_engines[False], both_engines[True]
    assert abs(seq["aco"] - bat["aco"]) < 2e-3
    # NOTE: after only 1-2 Adam steps the delta magnitudes are nearly
    # uniform (sign-like first updates), so the kept fraction runs high at
    # this toy scale; the paper-regime ~0.49 assertion lives in test_system.
    assert 0.2 < bat["aco"] < 0.75


def test_parity_participation_and_logs(both_engines):
    (trs, _), (trb, _) = both_engines[False], both_engines[True]
    assert np.array_equal(trs.participation, trb.participation)
    for ls, lb in zip(trs.logs, trb.logs):
        assert ls.participants == lb.participants
        assert ls.stalenesses == lb.stalenesses
        assert ls.forced == lb.forced
        assert ls.time == lb.time


def test_auto_engine_selection(data):
    """engine=None: sequential for the paper CNN on CPU; for small models
    the stacked engines win — sharded when the host has multiple devices
    AND the round is big enough to amortize the collectives, batched
    otherwise. Explicit flags (and the legacy batched= alias) always win."""
    from repro.core.feds3a import MIN_SHARD_ROWS
    on_cpu = jax.default_backend() == "cpu"
    D = len(jax.devices())
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=1))
    assert tr.batched == (not on_cpu)
    # the 10-client fixture admits ceil(0.6 * 10) = 6 participants — under
    # MIN_SHARD_ROWS per device on a 4-device host, so auto stays batched
    # (tiny rounds lose more to psum overhead than they gain from sharding;
    # measured at K=8, D=4 on CPU)
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=1, cnn=TEST_CNN))
    k = int(np.ceil(0.6 * tr.M))
    want = "sharded" if (D > 1 and k >= MIN_SHARD_ROWS * D) else "batched"
    assert tr.engine == want
    assert tr.batched is True
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=1, engine="batched",
                                          cnn=TEST_CNN))
    assert tr.engine == "batched"
    # legacy alias still maps onto engine= when engine is unset (it warns;
    # test_batched_kwarg_deprecated pins the warning itself)
    with pytest.deprecated_call():
        tr = FedS3ATrainer(data, FedS3AConfig(rounds=1, batched=False,
                                              cnn=TEST_CNN))
    assert tr.engine == "sequential"
    assert tr.batched is False
    with pytest.deprecated_call():
        tr = FedS3ATrainer(data, FedS3AConfig(rounds=1, batched=True,
                                              cnn=TEST_CNN))
    assert tr.engine == "batched"
    # engine= beats the legacy flag
    with pytest.deprecated_call():
        tr = FedS3ATrainer(data, FedS3AConfig(rounds=1, engine="sharded",
                                              batched=False, cnn=TEST_CNN))
    assert tr.engine == "sharded"


def test_batched_kwarg_deprecated(data):
    """FedS3AConfig(batched=...) is a deprecated alias for engine=: it must
    raise DeprecationWarning at trainer construction (where the engine is
    resolved) while keeping its historical behaviour, and engine= must stay
    silent."""
    import warnings
    with pytest.deprecated_call(match="engine="):
        tr = FedS3ATrainer(data, FedS3AConfig(rounds=1, batched=True,
                                              cnn=TEST_CNN))
    assert tr.engine == "batched"
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        FedS3ATrainer(data, FedS3AConfig(rounds=1, engine="batched",
                                         cnn=TEST_CNN))


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a client mesh")
def test_auto_engine_threshold_calibration():
    """Regression for the K/device calibration: a round under
    MIN_SHARD_ROWS participants per device auto-selects batched, a fleet
    above it auto-selects sharded."""
    from repro.core.feds3a import MIN_SHARD_ROWS
    from repro.data import make_fleet_dataset
    D = len(jax.devices())
    # K = ceil(0.5 * 8) = 4 participants on a 4-device host: 1 row/device
    small = make_fleet_dataset(8, scale=0.0008, seed=0)
    tr = FedS3ATrainer(small, FedS3AConfig(rounds=1, C=0.5, cnn=TEST_CNN,
                                           batch_size=50))
    assert tr.scheduler.k < MIN_SHARD_ROWS * D
    assert tr.engine == "batched"
    # K = ceil(0.5 * 64) = 32 participants: 8 rows/device
    big = make_fleet_dataset(64, scale=0.0008, seed=0)
    tr = FedS3ATrainer(big, FedS3AConfig(rounds=1, C=0.5, cnn=TEST_CNN,
                                         batch_size=50))
    assert tr.scheduler.k >= MIN_SHARD_ROWS * D
    assert tr.engine == "sharded"


# --- sync-free batched comm ------------------------------------------------
def test_encode_batch_no_host_sync(rng):
    """encode_batch returns device values only and defers ACO accounting —
    no int()/float() materialization per message."""
    comm = SparseComm("p0.2", use_kernel=False)
    flat = jax.random.normal(rng, (4, 4096))
    masked, stats = comm.encode_batch(flat, jnp.zeros_like(flat))
    assert isinstance(stats["nnz"], jax.Array)
    assert comm._pending_payload and comm._payload_host == 0.0
    # materializes only on read, then drains the pending list
    aco = comm.aco
    assert comm._pending_payload == []
    kept = float(jnp.sum(stats["nnz"])) / flat.size
    # value + index per stored element plus the host-tracked row_ptr
    expect = float(jnp.sum(stats["nnz"])) * 8 + comm.row_ptr_bytes
    assert abs(aco - expect / comm.dense_bytes) < 1e-6
    assert abs(kept - 0.2) < 0.1


def test_encode_batch_matches_sequential_encode(rng):
    """Row i of the batched encode == the sequential encode of tree i."""
    tree = {"a": jax.random.normal(rng, (64, 9)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (33,))}
    base = jax.tree.map(jnp.zeros_like, tree)
    seq = SparseComm("p0.3", use_kernel=False)
    delta_tree, stats = seq.encode(tree, base)
    bat = SparseComm("p0.3", use_kernel=False)
    flat = flatten_tree(tree)
    masked, bstats = bat.encode_batch(flat[None], jnp.zeros_like(flat)[None])
    np.testing.assert_allclose(np.asarray(masked[0]),
                               np.asarray(flatten_tree(delta_tree)))
    assert int(bstats["nnz"][0]) == int(stats["nnz"])
    assert abs(seq.aco - bat.aco) < 1e-9


def test_error_feedback_batch_roundtrip(rng):
    """Batched EF: repeated transmission of the same target converges."""
    comm = SparseComm("p0.3", use_kernel=False)
    target = jax.random.normal(rng, (2, 2048))
    recon = jnp.zeros_like(target)
    residual = jnp.zeros_like(target)
    for _ in range(12):
        masked, _, residual = comm.encode_batch(target, recon,
                                                residual_flat=residual)
        recon = recon + masked
    assert float(jnp.abs(recon - target).max()) < 1e-4


# --- stacked flatten/unflatten helpers -------------------------------------
def test_unflatten_stacked_roundtrip(rng):
    tree = {"a": jax.random.normal(rng, (5, 3)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (7,))}
    from repro.core.sparse_comm import flatten_stacked, stack_trees
    stacked = stack_trees([tree, jax.tree.map(lambda x: 2 * x, tree)])
    flat = flatten_stacked(stacked)
    assert flat.shape == (2, 22)
    np.testing.assert_allclose(np.asarray(flat[0]),
                               np.asarray(flatten_tree(tree)))
    back = unflatten_stacked(flat, tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(back[k][0]),
                                   np.asarray(tree[k]))
