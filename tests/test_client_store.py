"""PagedClientStore unit contract: the host-paged per-client state must be
indistinguishable from the resident layout through every access path —
deferred-write ordering, retirement-as-invalidation, zero-fill of
never-written pages, scatter-add CSR decode — and its device footprint must
be a function of the gather window (K), never the fleet (M). The
engine-level halves of the same contract (bit-identical runs, fault-trace
pinning) live in test_engine_parity.py / test_chaos.py.
"""
import numpy as np
import pytest

from repro.core.client_store import LAYOUTS, PagedClientStore

M, N, RCAP = 32, 40, 10


def _csr_page(rng, k):
    vals = rng.normal(size=(k, RCAP)).astype(np.float32)
    idx = np.stack([rng.choice(N, RCAP, replace=False)
                    for _ in range(k)]).astype(np.int32)
    return vals, idx


def test_scatter_gather_round_trip_csr():
    rng = np.random.default_rng(0)
    st = PagedClientStore(M, N, RCAP)
    ids = [3, 7, 21]
    vals, idx = _csr_page(rng, len(ids))
    st.scatter_csr(ids, vals, idx)
    gv, gi = st.gather_csr(ids)
    assert np.array_equal(np.asarray(gv), vals)
    assert np.array_equal(np.asarray(gi), idx)


def test_scatter_gather_round_trip_dense():
    rng = np.random.default_rng(1)
    st = PagedClientStore(M, N, RCAP, layout="dense")
    ids = [0, 31]
    rows = rng.normal(size=(2, N)).astype(np.float32)
    st.scatter_dense(ids, rows)
    assert np.array_equal(np.asarray(st.gather_dense(ids)), rows)
    assert np.array_equal(st.residual_row(31), rows[1])


def test_unwritten_and_foreign_rows_read_zero():
    rng = np.random.default_rng(2)
    st = PagedClientStore(M, N, RCAP)
    vals, idx = _csr_page(rng, 1)
    st.scatter_csr([5], vals, idx)
    gv, gi = st.gather_csr([4, 5, 6])
    assert not np.asarray(gv)[[0, 2]].any()
    assert not np.asarray(gi)[[0, 2]].any()
    assert np.array_equal(np.asarray(gv)[1], vals[0])
    assert not st.residual_row(4).any()


def test_deferred_queue_order_scatter_then_retire_zeroes():
    rng = np.random.default_rng(3)
    st = PagedClientStore(M, N, RCAP)
    vals, idx = _csr_page(rng, 1)
    st.scatter_csr([9], vals, idx)
    st.retire([9])                       # same-round fault after the upload
    assert not st.residual_row(9).any()
    assert not st.valid[9]


def test_deferred_queue_order_retire_then_scatter_keeps_data():
    rng = np.random.default_rng(4)
    st = PagedClientStore(M, N, RCAP)
    vals, idx = _csr_page(rng, 1)
    st.retire([9])
    st.scatter_csr([9], vals, idx)       # rejoiner writes after retirement
    assert st.residual_row(9).any()
    assert st.valid[9]


def test_residual_row_scatter_add_decodes_duplicate_columns():
    st = PagedClientStore(M, N, RCAP)
    vals = np.zeros((1, RCAP), np.float32)
    idx = np.zeros((1, RCAP), np.int32)
    vals[0, :3] = [1.0, 2.0, 4.0]
    idx[0, :3] = [7, 7, 12]              # duplicate column must ADD
    st.scatter_csr([0], vals, idx)
    row = st.residual_row(0)
    assert row[7] == 3.0 and row[12] == 4.0
    assert row.sum() == 7.0


def test_memmap_pages_persist_under_paged_dir(tmp_path):
    rng = np.random.default_rng(5)
    st = PagedClientStore(M, N, RCAP, paged_dir=tmp_path)
    vals, idx = _csr_page(rng, 2)
    st.scatter_csr([1, 2], vals, idx)
    st.flush()
    assert isinstance(st.res_vals, np.memmap)
    on_disk = np.load(tmp_path / "res_vals.npy", mmap_mode="r")
    assert np.array_equal(np.asarray(on_disk[[1, 2]]), vals)
    gv, _ = st.gather_csr([1, 2])
    assert np.array_equal(np.asarray(gv), vals)


def test_record_participation_counters():
    st = PagedClientStore(M, N, RCAP, layout="none")
    st.record_participation([2, 5], 0)
    st.record_participation([5], 3)
    assert st.part_count[5] == 2 and st.part_count[2] == 1
    assert st.last_round[5] == 3 and st.last_round[2] == 0
    assert st.last_round[0] == -1
    assert st.residual_store_bytes() == 0
    assert not st.residual_row(5).any()


def test_device_window_bytes_scale_with_k_not_m():
    rng = np.random.default_rng(6)
    small = PagedClientStore(M, N, RCAP)
    big = PagedClientStore(100 * M, N, RCAP)
    ids = [0, 1, 2, 3]
    for st in (small, big):
        vals, idx = _csr_page(rng, len(ids))
        st.scatter_csr(ids, vals, idx)
        st.gather_csr(ids)
    assert small.device_window_bytes() == big.device_window_bytes()
    assert big.host_bytes() > 50 * small.host_bytes()
    # queued writeback pages count as device bytes until flushed
    vals, idx = _csr_page(rng, len(ids))
    small.scatter_csr(ids, vals, idx)
    pending = small.device_window_bytes()
    assert pending > big.device_window_bytes()
    small.flush()
    assert small.device_window_bytes() < pending


def test_adopted_versions_count_toward_host_bytes():
    st = PagedClientStore(M, N, RCAP, layout="none")
    base = st.host_bytes()
    st.adopt_versions(np.zeros(M, np.int64), np.zeros(M, bool))
    assert st.host_bytes() == base + M * 8 + M


def test_rejects_unknown_layout():
    with pytest.raises(ValueError, match="layout"):
        PagedClientStore(M, N, RCAP, layout="sparse")
    assert LAYOUTS == ("csr", "dense", "none")


def test_trainer_rejects_paged_with_dense_base_store():
    from repro.configs.feds3a_cnn import CNNConfig
    from repro.core import FedS3AConfig, FedS3ATrainer
    from repro.data import make_dataset

    data = make_dataset("basic", scale=0.0015, seed=0)
    cnn = CNNConfig(name="feds3a-cnn-store", conv_filters=(8, 8), hidden=16)
    with pytest.raises(ValueError, match="paged"):
        FedS3ATrainer(data, FedS3AConfig(
            cnn=cnn, base_store="dense", client_store="paged"))
    with pytest.raises(ValueError, match="client_store"):
        FedS3ATrainer(data, FedS3AConfig(cnn=cnn, client_store="mapped"))
