"""Cross-engine parity matrix: sequential vs batched vs sharded.

Three round engines implement one algorithm; this suite pins them together
so they can never drift. Every engine must produce the IDENTICAL
participation/staleness/forced schedule (the scheduler is host-side and
deterministic) and the same metrics/ACO within float reduction-order
tolerance, across non-IID and balanced splits, staleness-tolerance
settings, and participant counts that do not divide the device count
(exercising the sharded engine's zero-weight padding rows).

conftest forces a 4-device CPU host, so the sharded engine really runs
shard_map over a 4-way ``clients`` mesh here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset

TEST_CNN = CNNConfig(name="feds3a-cnn-parity", conv_filters=(8, 8), hidden=16)

ENGINES = ("sequential", "batched", "sharded")

# (id, scenario, config overrides) — C values chosen so K = ceil(C*M) hits
# both divisible (K=8) and indivisible (K=5, 6) participant counts on the
# forced 4-device host
MATRIX = [
    ("noniid-tau2-k6", "basic", dict(C=0.6, tau=2)),
    ("noniid-tau1-k8", "basic", dict(C=0.8, tau=1)),
    ("balanced-tau3-k5", "balanced", dict(C=0.5, tau=3)),
    ("noniid-ef-k6", "basic", dict(C=0.6, tau=2, error_feedback=True)),
    # Pallas kernel path end to end: CSR compaction + fused scatter-add
    # aggregation + staleness_agg inside the sharded stages (interpret
    # mode on CPU)
    ("noniid-kernels-k6", "basic", dict(C=0.6, tau=2, use_kernels=True)),
    # legacy dense-masked wire format (masked dense deltas, counted nnz)
    # stays pinned across all three engines, including its EF path
    ("noniid-wire-dense-k6", "basic",
     dict(C=0.6, tau=2, wire_format="dense_masked", error_feedback=True)),
    # legacy dense base store (per-client base rows/matrix, per-target
    # distribution encodes): the sequential reference cell here IS the
    # pre-versioned reference implementation, so this row pins the dense
    # store's engines to it exactly as before the versioned default
    ("noniid-dense-store-k6", "basic",
     dict(C=0.6, tau=2, base_store="dense")),
    # quantized + packed wire format (csr_q): int8 values with per-row
    # absmax scales, int16 in-block offsets + block-count tables, the
    # dequantization error folded into the EF residual — the quantize /
    # pack / dequantizing-scatter pipeline must agree across all three
    # engines like every other format
    ("noniid-wire-csrq-k6", "basic",
     dict(C=0.6, tau=2, wire_format="csr_q", error_feedback=True)),
    # csr_q through the Pallas kernel path (quantize + compact + fused
    # aggregation in interpret mode) and the fp16 fallback without EF
    ("noniid-wire-csrq-kernels-k5", "basic",
     dict(C=0.5, tau=2, wire_format="csr_q", use_kernels=True,
          q_dtype="fp16")),
    # epochs > 1: every epoch folds its index into the client RNG key in
    # both the sequential loop and the batched lax.scan, so the fixed
    # paths stay pinned to each other (the old shared-key replay bug hid
    # here because both paths shared it)
    ("noniid-epochs2-k6", "basic", dict(C=0.6, tau=2, epochs=2)),
    # participant-paged client store: host-resident EF pages + a device
    # gather/scatter window of just the round's participants. Same cells
    # as the resident EF rows above, so the dedicated paged-vs-resident
    # test below can pin the two layouts bit-identical per engine.
    ("noniid-paged-ef-k6", "basic",
     dict(C=0.6, tau=2, error_feedback=True, client_store="paged")),
    ("noniid-paged-dense-wire-k6", "basic",
     dict(C=0.6, tau=2, wire_format="dense_masked", error_feedback=True,
          client_store="paged")),
]

# (paged case, resident twin) pairs — identical configs modulo client_store
PAGED_TWINS = [
    ("noniid-paged-ef-k6", "noniid-ef-k6"),
    ("noniid-paged-dense-wire-k6", "noniid-wire-dense-k6"),
]


@pytest.fixture(scope="module")
def datasets():
    return {s: make_dataset(s, scale=0.0015, seed=0)
            for s in ("basic", "balanced")}


@pytest.fixture(scope="module")
def matrix_runs(datasets):
    """Every (case, engine) cell, trained 3 rounds from the same seed."""
    out = {}
    for case, scenario, overrides in MATRIX:
        for engine in ENGINES:
            tr = FedS3ATrainer(datasets[scenario], FedS3AConfig(
                rounds=3, seed=0, engine=engine, cnn=TEST_CNN, **overrides))
            out[case, engine] = (tr, tr.train())
    return out


@pytest.mark.parametrize("case", [m[0] for m in MATRIX])
@pytest.mark.parametrize("engine", ["batched", "sharded"])
def test_schedule_identical(matrix_runs, case, engine):
    """Participation/staleness/forced schedules are scheduler-determined and
    must match the sequential reference exactly — no float tolerance."""
    ref, _ = matrix_runs[case, "sequential"]
    tr, _ = matrix_runs[case, engine]
    assert np.array_equal(ref.participation, tr.participation)
    for ls, le in zip(ref.logs, tr.logs):
        assert ls.participants == le.participants
        assert ls.stalenesses == le.stalenesses
        assert ls.forced == le.forced
        assert ls.time == le.time


@pytest.mark.parametrize("case", [m[0] for m in MATRIX])
@pytest.mark.parametrize("engine", ["batched", "sharded"])
def test_metrics_within_reduction_tolerance(matrix_runs, case, engine):
    """Same math, different reduction orders (vmap/lax.map batching, psum
    over the client mesh) — metrics must agree to float32 tolerance."""
    _, ref = matrix_runs[case, "sequential"]
    _, res = matrix_runs[case, engine]
    for k in ref["metrics"]:
        assert abs(ref["metrics"][k] - res["metrics"][k]) < 1e-4, (k, case)


@pytest.mark.parametrize("case", [m[0] for m in MATRIX])
@pytest.mark.parametrize("engine", ["batched", "sharded"])
def test_aco_within_quantile_flip_tolerance(matrix_runs, case, engine):
    """Delta elements sitting exactly at the sampled quantile threshold can
    flip under a different reduction order, bounding ACO drift at ~1e-3
    relative — far inside the paper-level signal (~0.49)."""
    _, ref = matrix_runs[case, "sequential"]
    _, res = matrix_runs[case, engine]
    assert abs(ref["aco"] - res["aco"]) < 2e-3, case


@pytest.mark.parametrize("engine", ENGINES)
def test_paged_store_bit_identical_to_resident(matrix_runs, engine):
    """client_store="paged" is a memory layout, not an algorithm change:
    for every engine the paged run must equal its resident twin EXACTLY —
    schedules, metrics and ACO, no float tolerance. The paged gather
    (host fancy-index + device transfer) decodes the same f32 values the
    resident row gather reads, so even the reduction order is unchanged."""
    for paged_case, resident_case in PAGED_TWINS:
        rtr, rres = matrix_runs[resident_case, engine]
        ptr, pres = matrix_runs[paged_case, engine]
        assert np.array_equal(rtr.participation, ptr.participation), \
            (paged_case, engine)
        for lr, lp in zip(rtr.logs, ptr.logs):
            assert lr.participants == lp.participants
            assert lr.stalenesses == lp.stalenesses
            assert lr.forced == lp.forced
        for k in rres["metrics"]:
            assert rres["metrics"][k] == pres["metrics"][k], \
                (k, paged_case, engine)
        assert rres["aco"] == pres["aco"], (paged_case, engine)


def test_paged_device_window_smaller_than_resident_equiv(matrix_runs):
    """The device window holds K participants, the resident equivalent all
    M clients — the paged headline (device bytes flat in M) shows up even
    at test scale as window < equivalent."""
    tr, _ = matrix_runs["noniid-paged-ef-k6", "batched"]
    assert tr.client_state_device_bytes() < \
        tr.client_state_resident_equiv_bytes()


def test_sharded_pads_indivisible_k(matrix_runs):
    """K=6 participants on 4 devices -> 8 padded rows; the pad rows must
    not leak into accounting: messages equals the sequential count."""
    ref, _ = matrix_runs["noniid-tau2-k6", "sequential"]
    tr, _ = matrix_runs["noniid-tau2-k6", "sharded"]
    assert tr.mesh.devices.size > 1
    assert tr.scheduler.k % tr.mesh.devices.size != 0
    assert tr.comm.messages == ref.comm.messages
    assert tr.comm.dense_bytes == ref.comm.dense_bytes


def test_sharded_base_versions_track_sequential(matrix_runs):
    ref, _ = matrix_runs["noniid-tau1-k8", "sequential"]
    tr, _ = matrix_runs["noniid-tau1-k8", "sharded"]
    assert np.array_equal(ref.base_versions, tr.base_versions)


def test_dense_store_base_versions_track_sequential(matrix_runs):
    """The legacy dense store keeps its per-engine version bookkeeping."""
    ref, _ = matrix_runs["noniid-dense-store-k6", "sequential"]
    tr, _ = matrix_runs["noniid-dense-store-k6", "sharded"]
    assert np.array_equal(ref.base_versions, tr.base_versions)


def test_padded_rows_helper():
    from repro.distributed.sharding import padded_rows
    assert padded_rows(6, 4) == 8
    assert padded_rows(8, 4) == 8
    assert padded_rows(1, 4) == 4
    assert padded_rows(0, 4) == 4      # never less than one row per shard
    assert padded_rows(5, 1) == 5


def test_engine_rejects_unknown():
    data = make_dataset("basic", scale=0.0015, seed=0)
    with pytest.raises(ValueError):
        FedS3ATrainer(data, FedS3AConfig(engine="warp", cnn=TEST_CNN))


def test_sharded_round_defers_all_accounting(datasets):
    """The sharded round is device-resident: after rounds, every ACO
    payload contribution is still a pending device scalar (materialized
    only when .aco is read) and the global model is a device array that
    was never pulled to host by the round itself."""
    tr = FedS3ATrainer(datasets["basic"], FedS3AConfig(
        rounds=2, seed=0, engine="sharded", cnn=TEST_CNN))
    for _ in range(2):
        tr.run_round()
    assert tr.comm._payload_host == 0.0
    assert len(tr.comm._pending_payload) == 4    # upload + distribute x2
    assert isinstance(tr._global_flat, jax.Array)
    assert tr.comm.aco > 0                        # the deferred read works
    assert tr.comm._pending_payload == []


# --- chunked parameter axis (ParamLayout streaming rounds) ------------------
# Two pins. First: the degenerate single-chunk layout IS the flat path — a
# chunk_size >= N resolves to no layout at all, so those cells must equal
# the existing flat matrix cells EXACTLY (bit-identity, no tolerance), per
# engine and wire format. Second: with a real multi-chunk layout the three
# engines stay pinned to each other (sequential == batched bitwise — same
# RNG stream, same stacked bodies — and sharded within the usual matrix
# tolerances). Chunked-vs-flat is NOT bit-identical by design: per-chunk
# quantile thresholds legitimately differ from per-row global quantiles.

# (id, flat twin in MATRIX, config overrides) — twins chosen so csr and
# csr_q (+EF) wires both get a single-chunk bit-identity pin
CHUNK_TWINS = [
    ("chunk-csr-k6", "noniid-tau2-k6", dict(C=0.6, tau=2)),
    ("chunk-csrq-ef-k6", "noniid-wire-csrq-k6",
     dict(C=0.6, tau=2, wire_format="csr_q", error_feedback=True)),
]

_CHUNKED_SIZE = 2600       # ~5 leaf-aligned chunks on the 10385-param CNN


@pytest.fixture(scope="module")
def chunk_runs(datasets):
    """Single-chunk (degenerate) and multi-chunk cells for every engine."""
    out = {}
    for case, _twin, overrides in CHUNK_TWINS:
        for engine in ENGINES:
            for label, size in (("one", 10**6), ("many", _CHUNKED_SIZE)):
                tr = FedS3ATrainer(datasets["basic"], FedS3AConfig(
                    rounds=3, seed=0, engine=engine, cnn=TEST_CNN,
                    chunk_size=size, **overrides))
                out[case, engine, label] = (tr, tr.train())
    return out


@pytest.mark.parametrize("case", [c[0] for c in CHUNK_TWINS])
@pytest.mark.parametrize("engine", ENGINES)
def test_single_chunk_bit_identical_to_flat(matrix_runs, chunk_runs, case,
                                            engine):
    """chunk_size >= N packs every leaf into one chunk, the layout resolves
    to flat, and the run routes through the historical code paths — so it
    must equal the flat matrix cell EXACTLY, schedules and floats alike."""
    twin = dict((c, t) for c, t, _ in CHUNK_TWINS)[case]
    tr, res = chunk_runs[case, engine, "one"]
    rtr, rres = matrix_runs[twin, engine]
    assert tr.layout is None and not tr.chunked
    assert np.array_equal(rtr.participation, tr.participation)
    for lr, lc in zip(rtr.logs, tr.logs):
        assert lr.participants == lc.participants
        assert lr.stalenesses == lc.stalenesses
        assert lr.forced == lc.forced
    for k in rres["metrics"]:
        assert rres["metrics"][k] == res["metrics"][k], (k, case, engine)
    assert rres["aco"] == res["aco"], (case, engine)


@pytest.mark.parametrize("case", [c[0] for c in CHUNK_TWINS])
def test_chunked_sequential_equals_batched_bitwise(chunk_runs, case):
    """All chunked engines share one stacked round body (the sequential
    engine runs it at K rows like the batched engine), so these two cells
    agree bitwise — same RNG stream, same reduction order."""
    _, ref = chunk_runs[case, "sequential", "many"]
    _, res = chunk_runs[case, "batched", "many"]
    for k in ref["metrics"]:
        assert ref["metrics"][k] == res["metrics"][k], (k, case)
    assert ref["aco"] == res["aco"], case


@pytest.mark.parametrize("case", [c[0] for c in CHUNK_TWINS])
def test_chunked_sharded_within_matrix_tolerance(chunk_runs, case):
    """The sharded chunked round shards only the training stage; encode and
    finalize stream unsharded, so it stays within the usual matrix
    tolerances of the sequential chunked reference."""
    rtr, ref = chunk_runs[case, "sequential", "many"]
    tr, res = chunk_runs[case, "sharded", "many"]
    assert np.array_equal(rtr.participation, tr.participation)
    for ls, le in zip(rtr.logs, tr.logs):
        assert ls.participants == le.participants
        assert ls.stalenesses == le.stalenesses
        assert ls.forced == le.forced
    for k in ref["metrics"]:
        assert abs(ref["metrics"][k] - res["metrics"][k]) < 1e-4, (k, case)
    assert abs(ref["aco"] - res["aco"]) < 2e-3, case


def test_chunked_layout_resolved_and_reported(chunk_runs):
    """The multi-chunk cells really stream: a resolved leaf-aligned layout,
    truthful wire_breakdown reporting, and a peak device delta bound that
    beats the flat engine's O(K*N)."""
    tr, _ = chunk_runs["chunk-csr-k6", "batched", "many"]
    ftr, _ = chunk_runs["chunk-csr-k6", "batched", "one"]
    assert tr.chunked and tr.layout.num_chunks > 1
    assert tr.layout.max_chunk <= _CHUNKED_SIZE
    wb = tr.comm.wire_breakdown()
    assert wb["layout"]["num_chunks"] == tr.layout.num_chunks
    assert tr.peak_delta_device_bytes() < ftr.peak_delta_device_bytes()


def test_per_layer_keep_frac_round_trips(datasets):
    """layer_keep_frac overrides land on their own chunks (leaf alignment)
    and the run still completes; the layout reports the overridden count."""
    tr = FedS3ATrainer(datasets["basic"], FedS3AConfig(
        rounds=2, seed=0, engine="batched", cnn=TEST_CNN,
        chunk_size=_CHUNKED_SIZE, layer_keep_frac={"conv": 0.05}))
    tr.train()
    desc = tr.layout.describe()
    assert desc["overridden_chunks"] >= 1
    assert tr.comm.wire_breakdown()["layout"]["overridden_chunks"] == \
        desc["overridden_chunks"]


# --- on-device k-means parity (the grouping host-sync removal) -------------
def test_kmeans_device_matches_host_on_separated_points():
    """Well-separated histograms -> identical assignments AND identical
    greedy-init center order, so grouped aggregation weights match."""
    from repro.core.grouping import (group_clients, group_clients_device,
                                     kmeans, kmeans_device, init_index)
    rng = np.random.default_rng(7)
    centers = np.eye(3)[:, :3]
    pts = np.concatenate([
        c + rng.normal(0, 0.02, (5, 3)) for c in centers]).astype(np.float32)
    host = group_clients(pts, 3, seed=0)
    dev = np.asarray(group_clients_device(jnp.asarray(pts), 3, seed=0))
    assert np.array_equal(host, dev)

    a_host, c_host = kmeans(pts, 3, seed=0)
    a_dev, c_dev = kmeans_device(jnp.asarray(pts), 3,
                                 init_idx=init_index(len(pts), 0))
    np.testing.assert_allclose(np.asarray(c_dev), c_host, atol=1e-5)


def test_kmeans_device_tie_tolerance():
    """Points equidistant between centers may tie-break differently under
    float32 (device) vs float64 (host) — the relaxed contract is only that
    both produce a valid partition of the requested size. This is why the
    cross-engine metric tolerance is 1e-4 rather than exact: a tie flip
    moves one client between groups and perturbs Eq. 10 weights at float
    epsilon scale on real (well-separated) pseudo-label histograms."""
    from repro.core.grouping import group_clients, group_clients_device
    pts = np.array([[0.0, 0.0], [1.0, 1.0], [0.5, 0.5], [0.5, 0.5]],
                   np.float32)
    host = group_clients(pts, 2, seed=0)
    dev = np.asarray(group_clients_device(jnp.asarray(pts), 2, seed=0))
    for a in (host, dev):
        assert a.shape == (4,)
        assert set(a) <= {0, 1}
        assert a[0] != a[1]        # the separated pair always splits


def test_kmeans_device_returns_device_array():
    """The sharded round's grouping must not sync: the assignment is a jax
    array and producing it triggers no host transfer of the histograms."""
    from repro.core.grouping import group_clients_device
    pts = jnp.asarray(np.random.default_rng(0).random((6, 9)), jnp.float32)
    out = group_clients_device(pts, 3, seed=0)
    assert isinstance(out, jax.Array)
