import os

# Force a multi-device CPU host BEFORE jax initializes its client, so the
# sharded fleet engine (shard_map over the ``clients`` mesh axis) is
# exercised by the suite everywhere — locally and in CI. A pre-set device
# count (e.g. from the CI workflow or a real multi-device host) wins.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
