import jax
import pytest


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
