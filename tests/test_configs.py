"""Config registry: all 10 assigned architectures, published param counts,
reduced-variant constraints, layer-pattern/scan-plan machinery."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_configs
from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM
from repro.models.lm import scan_plan

ASSIGNED = [
    "whisper-medium", "jamba-1.5-large-398b", "deepseek-67b",
    "deepseek-v2-236b", "qwen2-1.5b", "internlm2-20b", "xlstm-125m",
    "llama4-maverick-400b-a17b", "granite-8b", "pixtral-12b",
]

# published totals (billions), generous +-15% band
PUBLISHED = {
    "whisper-medium": 0.77, "jamba-1.5-large-398b": 398, "deepseek-67b": 67,
    "deepseek-v2-236b": 236, "qwen2-1.5b": 1.5, "internlm2-20b": 20,
    "xlstm-125m": 0.125, "llama4-maverick-400b-a17b": 400, "granite-8b": 8,
    "pixtral-12b": 12,
}
ACTIVE = {"jamba-1.5-large-398b": 94, "deepseek-v2-236b": 21,
          "llama4-maverick-400b-a17b": 17}


def test_all_assigned_registered():
    regs = list_configs()
    for a in ASSIGNED:
        assert a in regs


def test_four_input_shapes():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["train_4k"].global_batch == 256


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    n = cfg.param_count() / 1e9
    assert abs(n - PUBLISHED[arch]) / PUBLISHED[arch] < 0.35, (arch, n)
    if arch in ACTIVE:
        na = cfg.active_param_count() / 1e9
        assert abs(na - ACTIVE[arch]) / ACTIVE[arch] < 0.35, (arch, na)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 4
    assert r.d_model <= 512
    assert r.num_experts <= 4
    assert r.vocab_size <= 512


def test_jamba_pattern():
    cfg = get_config("jamba-1.5-large-398b")
    pat = cfg.layer_pattern()
    kinds = [k for k, _ in pat]
    assert kinds.count(ATTN) == 9           # 1 attention per 8 layers
    assert kinds.count(MAMBA) == 63
    assert sum(m for _, m in pat) == 36     # MoE every other layer
    prefix, period, reps = scan_plan(cfg)
    assert (prefix, period, reps) == (0, 8, 9)


def test_xlstm_pattern():
    cfg = get_config("xlstm-125m")
    kinds = [k for k, _ in cfg.layer_pattern()]
    assert kinds.count(SLSTM) == 1
    assert kinds.count(MLSTM) == 11


def test_deepseek_v2_first_dense():
    cfg = get_config("deepseek-v2-236b")
    assert not cfg.is_moe_layer(0)
    assert cfg.is_moe_layer(1)
    prefix, period, reps = scan_plan(cfg)
    assert prefix == 1 and period == 1 and reps == 59


def test_dense_scan_plan():
    cfg = get_config("deepseek-67b")
    assert scan_plan(cfg) == (0, 1, 95)
