"""Semi-async scheduler: paper Fig. 3 / Table II behaviour + hypothesis
properties of the FedS3A invariants, and checkpoint/restore round-trips."""
import math

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import fleet_ckpt
from repro.core.scheduler import (FleetStalledError, SemiAsyncScheduler,
                                  paper_latency)
from repro.core.traffic import TrafficModel


def test_paper_latency_fit():
    """§V-D3: C0 (78357 samples) ~317 s, C9 (16904) ~166 s."""
    assert abs(paper_latency(78357) - 317) < 2
    assert abs(paper_latency(16904) - 166) < 2


def test_fig3_pattern():
    """C=0.4, tau=2, 5 clients: the paper's illustration — two fast clients
    trigger each round; a very slow client eventually goes deprecated."""
    lats = [10.0, 11.0, 20.0, 21.0, 55.0]
    sch = SemiAsyncScheduler(lats, C=0.4, tau=2, jitter=0.0)
    parts0, stale0, forced0, t0 = sch.next_round()
    assert sorted(r.client for r in parts0) == [0, 1]
    assert all(s == 0 for s in stale0.values())
    # rounds tick fast; client 4 (55s) eventually exceeds tau=2 and is forced
    forced_any = []
    for _ in range(6):
        _, _, forced, _ = sch.next_round()
        forced_any += forced
    assert 4 in forced_any


def test_round_takes_exactly_k():
    sch = SemiAsyncScheduler([10, 20, 30, 40, 50, 60, 70, 80, 90, 100],
                             C=0.6, tau=2, jitter=0.0)
    parts, _, _, _ = sch.next_round()
    assert len(parts) == 6


@settings(max_examples=30, deadline=None)
@given(
    lats=st.lists(st.floats(min_value=1, max_value=500), min_size=3,
                  max_size=12),
    C=st.floats(min_value=0.1, max_value=1.0),
    tau=st.integers(min_value=0, max_value=4),
)
def test_scheduler_invariants(lats, C, tau):
    sch = SemiAsyncScheduler(lats, C=C, tau=tau, jitter=0.0)
    M = len(lats)
    k = max(int(math.ceil(C * M)), 1)
    prev_t = 0.0
    for r in range(8):
        parts, stale, forced, t = sch.next_round()
        # exactly ceil(C*M) participants per aggregation
        assert len(parts) == k
        # time is monotone
        assert t >= prev_t
        prev_t = t
        # after distribution nobody's in-flight run exceeds tau versions
        new_version = sch.state.round
        for (_, _, run) in sch.state.runs:
            assert new_version - run.base_version <= tau
        # forced clients restarted at the newest version
        for c in forced:
            assert sch.state.versions[c] == new_version


def test_tau_zero_with_jitter():
    """tau=0: zero staleness tolerance — every straggler is forced at every
    boundary, and latency jitter cannot push an in-flight run outside the
    (empty) window."""
    sch = SemiAsyncScheduler([10.0, 15.0, 20.0, 25.0, 30.0, 35.0],
                             C=0.5, tau=0, jitter=0.3, seed=3)
    for _ in range(10):
        parts, stale, forced, _ = sch.next_round()
        assert len(parts) == 3
        # with tau=0 a participant's base can only be the previous round
        # if it arrived without surviving a boundary; any survivor would
        # have been forced — so staleness is always 0
        assert all(s == 0 for s in stale.values())
        new_version = sch.state.round
        for (_, _, run) in sch.state.runs:
            assert new_version - run.base_version == 0
        for c in forced:
            assert sch.state.versions[c] == new_version


def test_full_participation_c_one():
    """C=1.0: the server waits for the whole fleet, so every round is a
    synchronous FedAvg-style round — all M participate, nobody is ever
    stale or forced, and the round time is the slowest client's latency."""
    lats = [10.0, 20.0, 30.0, 40.0]
    sch = SemiAsyncScheduler(lats, C=1.0, tau=2, jitter=0.0)
    prev_t = 0.0
    for _ in range(5):
        parts, stale, forced, t = sch.next_round()
        assert sorted(r.client for r in parts) == [0, 1, 2, 3]
        assert all(s == 0 for s in stale.values())
        assert forced == []
        assert t - prev_t == 40.0       # slowest client paces the round
        prev_t = t


def test_perma_forced_straggler():
    """A client whose latency exceeds tau rounds of fleet progress is
    forced at every boundary it survives to and NEVER participates — the
    paper's §IV-C2 deprecated-client regime as a permanent state."""
    lats = [10.0, 11.0, 1000.0]
    sch = SemiAsyncScheduler(lats, C=0.5, tau=2, jitter=0.0)
    forced_rounds = 0
    for r in range(12):
        parts, _, forced, _ = sch.next_round()
        assert 2 not in {run.client for run in parts}
        if 2 in forced:
            forced_rounds += 1
            assert sch.state.versions[2] == sch.state.round
    # forced at the first boundary where its gap exceeds tau, then again
    # every tau+1 rounds forever
    assert forced_rounds >= 3


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=99))
def test_all_clients_eventually_participate(seed):
    """With a bounded latency spread (the paper's measured spread is 1.9x),
    the staleness tolerance keeps every client in the training.

    NOTE: with an UNBOUNDED spread this property is false — a client much
    slower than tau rounds keeps being force-reset before finishing and never
    participates. That is exactly the paper's own §IV-C2 caveat about
    poorly-controlled staleness; hypothesis rediscovered it."""
    rng = np.random.default_rng(seed)
    lats = rng.uniform(150, 330, 8)       # ~paper's 166..317 s band
    sch = SemiAsyncScheduler(list(lats), C=0.5, tau=2, jitter=0.0)
    seen = set()
    for _ in range(12):
        parts, _, _, _ = sch.next_round()
        seen |= {r.client for r in parts}
    assert seen == set(range(8))


# -- checkpoint / restore ---------------------------------------------------
_RT_TRAFFIC = TrafficModel(crash_rate=0.15, upload_loss=0.1,
                           corrupt_prob=0.15, tail_sigma=0.4,
                           mean_online=2000.0, mean_offline=400.0,
                           late_join_frac=0.2)


def _rt_sched():
    lats = list(np.random.default_rng(3).uniform(150, 330, 10))
    return SemiAsyncScheduler(lats, C=0.5, tau=2, jitter=0.1, seed=11,
                              traffic=_RT_TRAFFIC, deadline=700.0,
                              quorum_floor=1)


def _round_trace(ev):
    return ([(r.client, r.base_version, round(r.finish_time, 9), r.fate)
             for r in ev.participants],
            sorted(ev.stale.items()), ev.forced, ev.lost, ev.corrupted,
            ev.departed, ev.rejoined, ev.crashes, ev.degraded,
            ev.deadline_hit, ev.quorum, ev.target_k, round(ev.time, 9))


def test_state_roundtrip_mid_stream():
    """state_dict taken mid-stream (runs in flight, churn timers armed,
    both RNGs advanced) restores onto a fresh scheduler and reproduces
    the identical next_round() sequence — directly AND through the
    fleet_ckpt msgpack codec (which must carry the 128-bit PCG64 words)."""
    a = _rt_sched()
    for _ in range(5):
        a.next_round()
    snap = a.state_dict()
    ref = [_round_trace(a.next_round()) for _ in range(8)]

    b = _rt_sched()
    b.load_state_dict(snap)
    assert [_round_trace(b.next_round()) for _ in range(8)] == ref

    c = _rt_sched()
    c.load_state_dict(fleet_ckpt.unpack(fleet_ckpt.pack(snap)))
    assert [_round_trace(c.next_round()) for _ in range(8)] == ref


def test_state_dict_rejects_wrong_fleet():
    snap = _rt_sched().state_dict()
    other = SemiAsyncScheduler([200.0, 250.0, 300.0])
    with pytest.raises(ValueError, match="fleet"):
        other.load_state_dict(snap)


def test_stalled_diagnosis_survives_restore():
    """A fleet that churns out raises FleetStalledError; a scheduler
    restored from a pre-stall checkpoint replays the same healthy rounds
    and then stalls at the same instant with the same diagnosis."""
    def mk():
        return SemiAsyncScheduler([200.0, 230.0, 260.0, 290.0, 310.0,
                                   330.0], C=0.5, tau=2, seed=5,
                                  traffic=TrafficModel(
                                      crash_rate=0.3, mean_online=900.0,
                                      mean_offline=5e8),
                                  quorum_floor=1)

    a = mk()
    snaps, stall_round, stall_msg = [], None, None
    for i in range(60):
        snaps.append(a.state_dict())
        try:
            a.next_round()
        except FleetStalledError as e:
            stall_round, stall_msg = i, str(e)
            break
    assert stall_round is not None, "profile never stalled; weak test"
    assert stall_round >= 1, "stalled before any healthy round"

    # restore at the brink: the very next call raises the same diagnosis
    b = mk()
    b.load_state_dict(fleet_ckpt.unpack(fleet_ckpt.pack(snaps[-1])))
    with pytest.raises(FleetStalledError) as exc:
        b.next_round()
    assert str(exc.value) == stall_msg

    # restore earlier: healthy rounds replay, then the identical stall
    j = max(0, stall_round - 2)
    c = mk()
    c.load_state_dict(snaps[j])
    for _ in range(stall_round - j):
        c.next_round()
    with pytest.raises(FleetStalledError) as exc:
        c.next_round()
    assert str(exc.value) == stall_msg
