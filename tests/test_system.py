"""End-to-end behaviour of the paper's system: FedS3A trains to high accuracy
on non-IID clients, halves the communication, and the semi-async scheduler's
round efficiency beats synchronous FL."""
import numpy as np
import pytest

from repro.core import FedAvgSSL, FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.006, seed=0)


@pytest.fixture(scope="module")
def feds3a_result(data):
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=6, seed=0))
    res = tr.train()
    res["trainer"] = tr
    return res


def test_feds3a_reaches_paper_accuracy_regime(feds3a_result):
    """Headline claim: >98% accuracy even on non-IID data (we accept >95%
    at this reduced scale/rounds)."""
    assert feds3a_result["metrics"]["accuracy"] > 0.95


def test_sparse_comm_halves_traffic(feds3a_result):
    """Paper: communication cost reduced by >50% (ACO ~0.49)."""
    assert feds3a_result["aco"] < 0.55


def test_round_efficiency_beats_synchronous(data, feds3a_result):
    """ART(FedS3A, C=0.6) < ART(synchronous FedAvg-All): the server never
    waits for the slowest client."""
    sync = FedAvgSSL(data, FedS3AConfig(rounds=2, seed=0), mode="all")
    res = sync.train()
    assert feds3a_result["art"] < res["art"]


def test_participation_matrix_consistent(feds3a_result):
    tr = feds3a_result["trainer"]
    part = tr.participation
    assert part.shape[0] == 6
    assert np.all(part.sum(axis=1) == 6)      # ceil(0.6 * 10) per round


def test_staleness_never_exceeds_tau_plus_one(feds3a_result):
    tr = feds3a_result["trainer"]
    for log in tr.logs:
        for s in log.stalenesses.values():
            assert s <= tr.cfg.tau + 1
