"""Beyond-paper error-feedback sparsification: masked-out delta mass is
carried forward instead of lost (fixes the paper's lossy §IV-F scheme)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_comm import SparseComm


def _tree(rng, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {"a": jax.random.normal(k1, (32, 16)) * scale,
            "b": jax.random.normal(k2, (64,)) * scale}


def test_error_feedback_recovers_full_delta(rng):
    """Transmitting the SAME target repeatedly with EF converges to it,
    while plain sparsification loses the masked mass forever."""
    base = _tree(rng, 0.0)
    target = _tree(jax.random.fold_in(rng, 1))

    comm = SparseComm(threshold="p0.3", use_kernel=False)
    residual = jax.tree.map(jnp.zeros_like, base)
    recon = base
    for _ in range(12):
        delta, _, residual = comm.encode(target, recon, residual=residual)
        recon = comm.apply(recon, delta)
    err_ef = max(float(jnp.abs(a - b).max())
                 for a, b in zip(jax.tree.leaves(recon),
                                 jax.tree.leaves(target)))

    comm2 = SparseComm(threshold="p0.3", use_kernel=False)
    recon2 = base
    delta, _ = comm2.encode(target, recon2)
    recon2 = comm2.apply(recon2, delta)
    err_plain = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(recon2),
                                    jax.tree.leaves(target)))
    assert err_ef < err_plain * 0.25
    assert err_ef < 0.05


def test_residual_is_the_masked_complement(rng):
    """Legacy dense-masked format: EF is lossless, the residual is exactly
    the masked-out complement."""
    base = _tree(rng, 0.0)
    new = _tree(jax.random.fold_in(rng, 2))
    comm = SparseComm(threshold="p0.5", use_kernel=False,
                      wire_format="dense_masked")
    zeros = jax.tree.map(jnp.zeros_like, base)
    delta, _, residual = comm.encode(new, base, residual=zeros)
    # delta + residual == full delta
    for d, r, n in zip(jax.tree.leaves(delta), jax.tree.leaves(residual),
                       jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(d + r), np.asarray(n),
                                   rtol=1e-5, atol=1e-6)


def test_csr_residual_is_the_truncated_complement(rng):
    """CSR format: the residual store keeps the top ``residual_frac`` of the
    complement by magnitude — what it drops is bounded by its own quantile
    threshold, and ``residual_frac=1.0`` recovers the lossless contract."""
    from repro.kernels.sparse_delta import local_quantile_thresholds
    base = _tree(rng, 0.0)
    new = _tree(jax.random.fold_in(rng, 2))
    zeros = jax.tree.map(jnp.zeros_like, base)

    # residual_frac=1.0: nothing is dropped (every nonzero is stored)
    comm = SparseComm(threshold="p0.5", use_kernel=False, residual_frac=1.0)
    delta, _, residual = comm.encode(new, base, residual=zeros)
    for d, r, n in zip(jax.tree.leaves(delta), jax.tree.leaves(residual),
                       jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(d + r), np.asarray(n),
                                   rtol=1e-5, atol=1e-6)

    # residual_frac=0.25: the store holds at most rcap entries, and every
    # dropped complement entry is under the per-row residual quantile
    comm = SparseComm(threshold="p0.5", use_kernel=False, residual_frac=0.25)
    delta, _, residual = comm.encode(new, base, residual=zeros)
    from repro.core.sparse_comm import flatten_tree
    full = np.asarray(flatten_tree(new))
    sent = np.asarray(flatten_tree(delta))
    res = np.asarray(flatten_tree(residual))
    n_params = full.size
    assert np.count_nonzero(res) <= comm.residual_capacity(n_params)
    dropped = full - sent - res
    raw_complement = (full - sent)[None, :]
    r_thr = float(local_quantile_thresholds(jnp.asarray(raw_complement),
                                            comm.residual_frac)[0])
    assert np.abs(dropped).max() <= r_thr + 1e-7


def test_trainer_error_feedback_mode_runs():
    from repro.core import FedS3AConfig, FedS3ATrainer
    from repro.data import make_dataset
    data = make_dataset("basic", scale=0.004, seed=0)
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=2, error_feedback=True))
    res = tr.train()
    assert res["metrics"]["accuracy"] > 0.8
    assert res["aco"] < 0.6


@pytest.mark.parametrize("engine", ["sequential", "batched", "sharded"])
def test_forced_restart_resets_residual(engine):
    """Pinned contract (see the SparseComm docstring): a deprecated
    client's forced restart discards its EF residual along with its
    in-flight trajectory — the residual was accumulated against a base the
    client no longer holds, so re-offering it would inject stale drift.
    tau=0 forces every straggler each round, so the scenario is hit
    immediately; at least one forced client must have participated before
    (i.e. actually carried a residual) for the test to mean anything."""
    import jax as _jax
    if engine == "sharded" and len(_jax.devices()) < 2:
        pytest.skip("needs a client mesh")
    from repro.configs.feds3a_cnn import CNNConfig
    from repro.core import FedS3AConfig, FedS3ATrainer
    from repro.data import make_dataset
    cnn = CNNConfig(name="feds3a-cnn-forced", conv_filters=(8, 8), hidden=16)
    data = make_dataset("basic", scale=0.0015, seed=0)
    # C=0.8, tau=0: wide rounds force recent participants quickly (measured:
    # a previously-participating client is forced within 10 rounds)
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=10, seed=0, engine=engine, tau=0, C=0.8, error_feedback=True,
        cnn=cnn))
    participated, reset_checked = set(), 0
    for _ in range(10):
        if reset_checked:
            break
        log = tr.run_round()
        for i in log.forced:
            if engine == "sequential":
                assert tr.clients[i].get("residual") is None
            elif engine == "batched":
                assert float(jnp.abs(tr._residual_rows[i]).sum()) == 0.0
            else:
                assert float(jnp.abs(tr._res_vals[i]).sum()) == 0.0
            if i in participated:
                reset_checked += 1      # had a real residual before reset
        participated.update(log.participants)
    assert reset_checked > 0


def test_sharded_ef_uses_sparse_residual_store():
    """The sharded engine under the CSR format keeps per-client residuals
    in capacity-bounded CSR rows — no dense (M, N) residual matrix — and
    the store is strictly smaller than the dense equivalent it replaced."""
    import jax as _jax
    import pytest
    if len(_jax.devices()) < 2:
        pytest.skip("needs a client mesh")
    from repro.configs.feds3a_cnn import CNNConfig
    from repro.core import FedS3AConfig, FedS3ATrainer
    from repro.data import make_dataset
    cnn = CNNConfig(name="feds3a-cnn-ef", conv_filters=(8, 8), hidden=16)
    data = make_dataset("basic", scale=0.0015, seed=0)
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=2, seed=0, engine="sharded", error_feedback=True, cnn=cnn))
    for _ in range(2):
        tr.run_round()
    assert not hasattr(tr, "_residual_mat")
    n = tr._global_flat.shape[0]
    rcap = tr.comm.residual_capacity(n)
    assert tr._res_vals.shape == (tr.M, rcap)
    assert tr._res_idx.shape == (tr.M, rcap)
    assert rcap < n
    assert tr.residual_store_bytes() < tr.M * n * 4
    # participants that ran carry a real residual
    assert float(jnp.abs(tr._res_vals).sum()) > 0
