"""Beyond-paper error-feedback sparsification: masked-out delta mass is
carried forward instead of lost (fixes the paper's lossy §IV-F scheme)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_comm import SparseComm, tree_add


def _tree(rng, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {"a": jax.random.normal(k1, (32, 16)) * scale,
            "b": jax.random.normal(k2, (64,)) * scale}


def test_error_feedback_recovers_full_delta(rng):
    """Transmitting the SAME target repeatedly with EF converges to it,
    while plain sparsification loses the masked mass forever."""
    base = _tree(rng, 0.0)
    target = _tree(jax.random.fold_in(rng, 1))

    comm = SparseComm(threshold="p0.3", use_kernel=False)
    residual = jax.tree.map(jnp.zeros_like, base)
    recon = base
    for _ in range(12):
        delta, _, residual = comm.encode(target, recon, residual=residual)
        recon = comm.apply(recon, delta)
    err_ef = max(float(jnp.abs(a - b).max())
                 for a, b in zip(jax.tree.leaves(recon),
                                 jax.tree.leaves(target)))

    comm2 = SparseComm(threshold="p0.3", use_kernel=False)
    recon2 = base
    delta, _ = comm2.encode(target, recon2)
    recon2 = comm2.apply(recon2, delta)
    err_plain = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(recon2),
                                    jax.tree.leaves(target)))
    assert err_ef < err_plain * 0.25
    assert err_ef < 0.05


def test_residual_is_the_masked_complement(rng):
    base = _tree(rng, 0.0)
    new = _tree(jax.random.fold_in(rng, 2))
    comm = SparseComm(threshold="p0.5", use_kernel=False)
    zeros = jax.tree.map(jnp.zeros_like, base)
    delta, _, residual = comm.encode(new, base, residual=zeros)
    # delta + residual == full delta
    for d, r, n in zip(jax.tree.leaves(delta), jax.tree.leaves(residual),
                       jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(d + r), np.asarray(n),
                                   rtol=1e-5, atol=1e-6)


def test_trainer_error_feedback_mode_runs():
    from repro.core import FedS3AConfig, FedS3ATrainer
    from repro.data import make_dataset
    data = make_dataset("basic", scale=0.004, seed=0)
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=2, error_feedback=True))
    res = tr.train()
    assert res["metrics"]["accuracy"] > 0.8
    assert res["aco"] < 0.6
