"""Chaos harness: random fault schedules against the fleet (ISSUE 6).

Four layers of assertion, cheapest first:

* **scheduler liveness** — under hypothesis-drawn fault profiles every
  ``next_round`` either terminates with a legal quorum or raises the clear
  :class:`FleetStalledError`; never a hang, never a bare heap error;
* **ring-eviction safety under churn** — the versioned store driven by raw
  scheduler fault traces never trips its eviction hard-error: departures
  detach, in-window rejoiners ride the chain suffix, evicted rejoiners take
  the accounted full-model resync;
* **residual hygiene** — after every faulted round, the EF residuals of
  forced / lost / departed / rejoined clients are retired (their mass was
  accumulated against a base that no longer exists for them);
* **the acceptance scenario** — 50 rounds at crash 10% / loss 5% with churn
  on EVERY engine: no hang or exception, the fault trace and all
  trace-derived round metrics bit-identical across engines, the ring-resync
  path exercised at least once, model metrics within the parity harness's
  float tolerances.

``CHAOS_SEED`` (env) shifts every fault stream — CI sweeps a small seed set
so the suite never ossifies around one lucky trace.
"""
import os

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import (REFERENCE_CHURN, FedS3AConfig, FedS3ATrainer,
                        FleetStalledError, TrafficModel, VersionedBaseStore)
from repro.core.scheduler import SemiAsyncScheduler
from repro.core.sparse_comm import SparseComm
from repro.data import make_dataset

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
TEST_CNN = CNNConfig(name="feds3a-cnn-chaos", conv_filters=(8, 8), hidden=16)
ENGINES = ("sequential", "batched", "sharded")

# the paper's measured 166..317 s client latency band
LATS_10 = list(np.linspace(160.0, 320.0, 10))


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


def _trace(trainer):
    """The schedule-derived portion of a run's logs — everything that must
    be BIT-identical across engines replaying the same fault trace."""
    return [(l.participants, dict(l.stalenesses), l.forced, l.lost,
             l.departed, l.rejoined, l.resynced, l.quorum, l.target_k,
             l.degraded, l.deadline_hit, l.crashes, round(l.time, 9))
            for l in trainer.logs]


# --- scheduler liveness under random fault schedules -------------------------
@settings(max_examples=25, deadline=None)
@given(
    crash=st.floats(min_value=0.0, max_value=0.5),
    loss=st.floats(min_value=0.0, max_value=0.4),
    sigma=st.floats(min_value=0.0, max_value=1.2),
    mean_online=st.floats(min_value=300.0, max_value=5000.0),
    mean_offline=st.floats(min_value=100.0, max_value=1500.0),
    late=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=9999),
)
def test_liveness_random_fault_schedules(crash, loss, sigma, mean_online,
                                         mean_offline, late, seed):
    """Every round under an arbitrary fault profile either terminates with
    quorum_floor <= quorum <= k, or raises the explicit FleetStalledError —
    never an IndexError, never an unbounded spin."""
    traffic = TrafficModel(crash_rate=crash, upload_loss=loss,
                           tail_sigma=sigma, mean_online=mean_online,
                           mean_offline=mean_offline, late_join_frac=late)
    sch = SemiAsyncScheduler(LATS_10, C=0.6, tau=2, jitter=0.05,
                             seed=seed + 131 * CHAOS_SEED, traffic=traffic,
                             deadline=900.0, quorum_floor=1)
    prev_t = 0.0
    for _ in range(30):
        try:
            ev = sch.next_round()
        except FleetStalledError:
            break                       # a legal, clearly-reported outcome
        assert 1 <= ev.quorum <= sch.k
        assert ev.quorum == len(ev.participants)
        if ev.quorum < sch.k:
            assert ev.degraded
        assert ev.time >= prev_t
        prev_t = ev.time
        # the staleness window survives every fault: no kept in-flight run
        # exceeds tau versions behind
        for (_, seq, run) in sch.state.runs:
            if seq not in sch.state.cancelled:
                assert sch.state.round - run.base_version <= sch.tau


# --- ring-eviction safety + resync accounting under churn --------------------
@settings(max_examples=25, deadline=None)
@given(
    crash=st.floats(min_value=0.0, max_value=0.3),
    loss=st.floats(min_value=0.0, max_value=0.2),
    mean_online=st.floats(min_value=400.0, max_value=3000.0),
    mean_offline=st.floats(min_value=200.0, max_value=2500.0),
    late=st.floats(min_value=0.0, max_value=0.4),
    tau=st.integers(min_value=0, max_value=3),
    seed=st.integers(min_value=0, max_value=9999),
)
def test_ring_eviction_safe_under_churn(crash, loss, mean_online,
                                        mean_offline, late, tau, seed):
    """Drive a VersionedBaseStore with raw scheduler fault traces (the same
    detach / advance / broadcast / resync sequence the trainers run, minus
    the learning): the eviction hard-error must never fire, attached clients
    stay inside the staleness window, and every rejoiner lands at the
    current version through exactly one of the two re-base paths."""
    traffic = TrafficModel(crash_rate=crash, upload_loss=loss,
                           mean_online=mean_online,
                           mean_offline=mean_offline, late_join_frac=late)
    sch = SemiAsyncScheduler(LATS_10, C=0.6, tau=tau,
                             seed=seed + 131 * CHAOS_SEED, traffic=traffic,
                             deadline=900.0, quorum_floor=1)
    import jax.numpy as jnp
    flat = jnp.zeros(8, jnp.float32)
    store = VersionedBaseStore(flat, M=len(LATS_10), tau=tau)
    store.detach(sch.initial_offline)
    comm = SparseComm("p0.5", use_kernel=False, enabled=False)
    resyncs = 0
    for _ in range(25):
        try:
            ev = sch.next_round()
        except FleetStalledError:
            break
        online = sch.state.online
        new_version = store.version + 1
        chain, resync = store.split_rejoined(ev.rejoined, new_version)
        targets = sorted({r.client for r in ev.participants
                          if online[r.client]}
                         | set(ev.forced) | set(ev.lost) | set(chain))
        store.detach(ev.departed)
        store.advance(flat + new_version, {"stored": 4}, new_version)
        store.account_distribution(comm, targets)
        store.resync(comm, resync)
        resyncs += len(resync)
        attached = ~store.detached
        assert (store.version - store.client_version[attached]
                <= tau + 1).all()
        for c in ev.rejoined:
            assert store.client_version[c] == store.version
            assert not store.detached[c]
    # resyncs are never free: the dense unicast is on both ledgers
    if resyncs:
        assert store.dist_payload_bytes() >= resyncs * store.n * 4


# --- stall + degradation edges ----------------------------------------------
def test_fleet_stalled_error_not_heap_error():
    """A fleet that churns out below the quorum floor raises the explicit
    FleetStalledError — not a bare IndexError, not an infinite loop."""
    traffic = TrafficModel(mean_online=1e-6, mean_offline=1e12)
    sch = SemiAsyncScheduler([10.0, 12.0, 14.0], C=1.0, tau=2,
                             seed=CHAOS_SEED, traffic=traffic)
    with pytest.raises(FleetStalledError, match="quorum floor"):
        for _ in range(5):
            sch.next_round()


def test_degraded_round_at_deadline():
    """k unreachable by the deadline -> aggregate the partial quorum at the
    deadline instant and report the degradation; the straggler's upload is
    not consumed by the cut-short round."""
    sch = SemiAsyncScheduler([10.0, 11.0, 12.0, 13.0, 900.0], C=1.0, tau=2,
                             jitter=0.0, deadline=50.0, quorum_floor=2)
    ev = sch.next_round()
    assert ev.degraded and ev.deadline_hit
    assert ev.quorum == 4 and ev.target_k == 5
    assert sorted(r.client for r in ev.participants) == [0, 1, 2, 3]
    assert ev.time == 50.0
    # the slow client is still in flight, not dropped
    live = {run.client for (_, seq, run) in sch.state.runs
            if seq not in sch.state.cancelled}
    assert 4 in live


def test_quorum_floor_validation():
    with pytest.raises(ValueError):
        SemiAsyncScheduler([10.0, 20.0], C=1.0, quorum_floor=0)
    with pytest.raises(ValueError):
        SemiAsyncScheduler([10.0, 20.0], C=1.0, quorum_floor=3)
    with pytest.raises(ValueError):
        SemiAsyncScheduler([10.0, 20.0], C=1.0, deadline=0.0)


def test_traffic_model_validation():
    with pytest.raises(ValueError):
        TrafficModel(crash_rate=0.99)       # starves the fleet
    with pytest.raises(ValueError):
        TrafficModel(upload_loss=-0.1)
    with pytest.raises(ValueError):
        TrafficModel(late_join_frac=1.5)
    with pytest.raises(ValueError):
        TrafficModel(mean_online=0.0)


def test_fault_free_trace_unchanged_by_fault_plumbing():
    """traffic=None reproduces the pre-fault scheduler draw-for-draw: the
    fault RNG is a separate stream and the legacy 4-tuple unpacking still
    works."""
    a = SemiAsyncScheduler(LATS_10, C=0.6, tau=2, jitter=0.05, seed=7)
    b = SemiAsyncScheduler(LATS_10, C=0.6, tau=2, jitter=0.05, seed=7,
                           deadline=1e9, quorum_floor=1)
    for _ in range(6):
        parts_a, stale_a, forced_a, t_a = a.next_round()
        ev = b.next_round()
        assert [r.client for r in parts_a] == \
            [r.client for r in ev.participants]
        assert stale_a == ev.stale and forced_a == ev.forced
        assert t_a == ev.time
        assert not ev.degraded and not ev.lost and not ev.rejoined


def test_dense_store_rejects_traffic(data):
    with pytest.raises(ValueError, match="versioned"):
        FedS3ATrainer(data, FedS3AConfig(
            base_store="dense", traffic=REFERENCE_CHURN, cnn=TEST_CNN))


# --- trainer-level fault accounting ------------------------------------------
def test_bytes_ledger_counts_only_delivered_uploads(data):
    """With sparsification disabled every message is exactly n*4 bytes, so
    the whole wire ledger is an exact arithmetic identity of the fault
    trace: one upload per DELIVERED participant (lost uploads absent), one
    dense broadcast per round with targets, one dense unicast per resync."""
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=15, seed=CHAOS_SEED, engine="batched", cnn=TEST_CNN,
        sparse_comm=False, traffic=REFERENCE_CHURN, round_deadline=700.0))
    tr.train()
    n = int(tr._global_flat.shape[0])
    uploads = rounds_with_targets = resyncs = lost = 0
    for l in tr.logs:
        uploads += len(l.participants)
        resyncs += len(l.resynced)
        lost += len(l.lost)
        online_parts = set(l.participants) - (set(l.departed)
                                              - set(l.rejoined))
        chain = set(l.rejoined) - set(l.resynced)
        if online_parts | set(l.forced) | set(l.lost) | chain:
            rounds_with_targets += 1
    assert lost > 0, "profile produced no lost uploads; weak test"
    expected = 4 * n * (uploads + rounds_with_targets + resyncs)
    assert tr.comm.payload_bytes == expected
    assert tr.comm.messages == uploads + rounds_with_targets + resyncs


def test_lost_quantized_uploads_book_zero_bytes(data):
    """csr_q under faults: a lost upload's quantized payload never reaches
    the ledger. One message per DELIVERED upload (lost absent) and per
    resync, between 1 and tau+1 chain-suffix payloads per broadcast round,
    the ledgers of the two CSR formats structurally identical over the
    bit-identical fault trace, the dense-equivalent ledger an exact n*4
    multiple of the message count — and the quantized run moves well under
    half the payload bytes of the f32 CSR twin."""
    runs = {}
    for wf in ("csr", "csr_q"):
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=15, seed=CHAOS_SEED, engine="batched", cnn=TEST_CNN,
            wire_format=wf, traffic=REFERENCE_CHURN, round_deadline=700.0))
        tr.train()
        runs[wf] = tr
    ref, tr = runs["csr"], runs["csr_q"]
    assert _trace(ref) == _trace(tr)     # wire format never touches faults
    n = int(tr._global_flat.shape[0])
    uploads = rounds_with_targets = resyncs = lost = 0
    for l in tr.logs:
        uploads += len(l.participants)
        resyncs += len(l.resynced)
        lost += len(l.lost)
        online_parts = set(l.participants) - (set(l.departed)
                                              - set(l.rejoined))
        chain = set(l.rejoined) - set(l.resynced)
        if online_parts | set(l.forced) | set(l.lost) | chain:
            rounds_with_targets += 1
    assert lost > 0, "profile produced no lost uploads; weak test"
    assert tr.comm.messages == ref.comm.messages
    floor = uploads + rounds_with_targets + resyncs
    cap = uploads + rounds_with_targets * (tr.cfg.tau + 1) + resyncs
    assert floor <= tr.comm.messages <= cap
    assert tr.comm.dense_bytes == 4 * n * tr.comm.messages
    # int8 values + int16 offsets vs f32 pairs: same stored elements
    # (identical trace + thresholds), a fraction of the bytes
    assert tr.comm.payload_bytes < 0.45 * ref.comm.payload_bytes


@pytest.mark.parametrize("engine", ["sequential", "batched"])
def test_residual_hygiene_under_faults(data, engine):
    """After every faulted round, the EF residuals of forced / lost /
    departed / rejoined clients are retired — their mass was accumulated
    against a base those clients no longer hold."""
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=10, seed=CHAOS_SEED, engine=engine, cnn=TEST_CNN,
        error_feedback=True, traffic=REFERENCE_CHURN, round_deadline=700.0))
    retired_any = 0
    for _ in range(10):
        log = tr.run_round()
        retired = (set(log.forced) | set(log.lost) | set(log.departed)
                   | set(log.rejoined))
        retired_any += len(retired)
        for i in retired:
            if engine == "sequential":
                assert "residual" not in tr.clients[i]
            else:
                assert not np.asarray(tr._residual_rows[i]).any()
    assert retired_any > 0, "profile produced no retirements; weak test"


def test_paged_store_pins_fault_trace_and_retires_pages(data):
    """client_store="paged" under REFERENCE_CHURN: the PR 5 fault hooks
    (residual retirement on force/loss/churn, rejoiner resync) become page
    operations, and the run must stay pinned to the resident layout —
    bit-identical fault trace, fleet health dict and model metrics — while
    every retired client's page reads back as an all-zero residual."""
    mk = lambda store: FedS3ATrainer(data, FedS3AConfig(
        rounds=12, seed=CHAOS_SEED, engine="batched", cnn=TEST_CNN,
        error_feedback=True, traffic=REFERENCE_CHURN, round_deadline=700.0,
        client_store=store))
    ref = mk("resident")
    tr = mk("paged")
    retired_any = 0
    for _ in range(12):
        ref.run_round()
        log = tr.run_round()
        retired = (set(log.forced) | set(log.lost) | set(log.departed)
                   | set(log.rejoined))
        retired_any += len(retired)
        for i in retired:
            assert not tr.cstore.residual_row(i).any(), i
    assert retired_any > 0, "profile produced no retirements; weak test"
    assert _trace(tr) == _trace(ref), "paged fault trace diverged"
    ref_out, out = ref.evaluate(), tr.evaluate()
    for k in ref_out:
        assert out[k] == ref_out[k], k     # same layout math: EXACT equality
    assert tr.comm.aco == ref.comm.aco


# --- the acceptance scenario -------------------------------------------------
def test_acceptance_50_rounds_all_engines_bit_identical(data):
    """ISSUE 6 acceptance: crash 10% / loss 5% / churn on, 50 rounds on
    every engine — no hang or exception, bit-identical fault trace and
    trace-derived metrics across engines, the ring-resync path exercised at
    least once, model metrics inside the parity tolerances."""
    runs = {}
    for engine in ENGINES:
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=50, seed=CHAOS_SEED, engine=engine, cnn=TEST_CNN,
            error_feedback=True, traffic=REFERENCE_CHURN,
            round_deadline=700.0, quorum_floor=1))
        out = tr.train()
        runs[engine] = (tr, out)
        assert out["rounds"] == 50

    ref_tr, ref_out = runs["sequential"]
    assert ref_out["fleet"]["resyncs"] >= 1, "ring-resync path never fired"
    assert ref_out["fleet"]["crashes"] > 0
    assert ref_out["fleet"]["lost_uploads"] > 0
    assert ref_out["fleet"]["departures"] > 0
    ref_trace = _trace(ref_tr)
    for engine in ENGINES[1:]:
        tr, out = runs[engine]
        # schedule-derived state: EXACT equality, field for field
        assert _trace(tr) == ref_trace, f"{engine} fault trace diverged"
        assert out["fleet"] == ref_out["fleet"]
        assert out["art"] == ref_out["art"]
        # model metrics: engines differ only by reduction order
        for key in ("accuracy", "f1"):
            assert abs(out["metrics"][key] - ref_out["metrics"][key]) < 1e-4
        assert abs(out["aco"] - ref_out["aco"]) < 2e-2
