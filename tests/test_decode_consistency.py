"""Decode-path correctness: teacher-forced decode logits must match the full
forward logits position-by-position — exercises KV caches, MLA latent cache,
mamba conv/ssm state and xLSTM recurrent state against the parallel forms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm

CASES = ["qwen2-1.5b", "deepseek-v2-236b", "jamba-1.5-large-398b",
         "xlstm-125m", "whisper-medium", "pixtral-12b"]


@pytest.mark.parametrize("arch", CASES)
def test_prefill_then_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    # fp32 + no expert capacity drops (capacity dropping is batch-global, so
    # prefill-vs-forward token counts would legitimately diverge otherwise)
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=8.0)
    params = lm.init_params(cfg, rng)
    B, S, K = 1, 16, 8      # prefill K tokens, decode the rest
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.num_encoder_positions, cfg.d_model))
    if cfg.num_vision_patches:
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.num_vision_patches, cfg.d_model))
    P = cfg.num_vision_patches or 0

    full_logits, _, _ = lm.forward(cfg, params, batch, remat=False)

    pre = {**batch, "tokens": tokens[:, :K]}
    last, cache = lm.prefill(cfg, params, pre, S + P)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, P + K - 1]),
        rtol=2e-3, atol=2e-3)

    # teacher-forced decode for the remaining tokens
    for i in range(K, S):
        logits, cache = lm.decode_step(cfg, params, tokens[:, i], cache,
                                       jnp.int32(P + i))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, P + i]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} step {i}")
