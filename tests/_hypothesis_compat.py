"""Use real hypothesis when installed; otherwise a tiny deterministic shim.

The shim covers exactly the subset this suite uses — ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=...)`` and the
``integers`` / ``floats`` / ``lists`` / ``sampled_from`` / ``booleans``
strategies — by drawing ``max_examples`` pseudo-random examples from a fixed
seed. No shrinking, no database; it keeps the property tests running in
environments without the dependency.
"""
from __future__ import annotations

__all__ = ["given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    import functools
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda r: r.choice(options))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda r: [elements.draw(r) for _ in
                                        range(r.randint(min_size, max_size))])

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    import inspect

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # @settings may sit above or below @given
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rnd = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco


__all__ = ["given", "settings", "st"]
