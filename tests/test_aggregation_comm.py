"""Aggregation (Eq. 7-10) and sparse-diff communication (§IV-F)."""
import jax
import jax.numpy as jnp
import numpy as np
from tests._hypothesis_compat import given, settings, st

from repro.core import aggregation as agg
from repro.core.functions import staleness_fn
from repro.core.grouping import group_clients
from repro.core.sparse_comm import SparseComm, flatten_tree, unflatten_like


def _tree(rng, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {"a": jax.random.normal(k1, (7, 5)) * scale,
            "b": jax.random.normal(k2, (11,)) * scale}


def test_aggregate_flat_matches_numpy(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(4)]
    server = _tree(jax.random.fold_in(rng, 99))
    sizes = [10, 20, 30, 40]
    stal = [0, 1, 0, 2]
    g = staleness_fn("exponential")
    fw = 0.3
    out = agg.aggregate(server, clients, data_sizes=sizes, stalenesses=stal,
                        g_fn=g, f_weight=fw, groups=None)
    w = np.array(sizes, float) * np.array([g(s) for s in stal])
    w = w / w.sum()
    for key in ("a", "b"):
        expect = fw * np.asarray(server[key]) + (1 - fw) * sum(
            wi * np.asarray(c[key]) for wi, c in zip(w, clients))
        np.testing.assert_allclose(np.asarray(out[key]), expect, rtol=1e-5)


def test_aggregate_single_group_equals_flat(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    server = _tree(jax.random.fold_in(rng, 99))
    kw = dict(data_sizes=[1, 2, 3], stalenesses=[0, 0, 1],
              g_fn=staleness_fn("polynomial"), f_weight=0.4)
    flat = agg.aggregate(server, clients, groups=None, **kw)
    grouped = agg.aggregate(server, clients, groups=np.zeros(3, int), **kw)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(flat[key]),
                                   np.asarray(grouped[key]), rtol=1e-5)


def test_aggregate_kernel_path_matches(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    server = _tree(jax.random.fold_in(rng, 99))
    kw = dict(data_sizes=[5, 5, 5], stalenesses=[0, 1, 2],
              g_fn=staleness_fn("exponential"), f_weight=0.25, groups=None)
    a = agg.aggregate(server, clients, use_kernel=False, **kw)
    b = agg.aggregate(server, clients, use_kernel=True, **kw)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(a[key]), np.asarray(b[key]),
                                   rtol=1e-4, atol=1e-5)


def test_fedavg_weights(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(2)]
    out = agg.fedavg(clients, [1, 3])
    expect = 0.25 * np.asarray(clients[0]["a"]) + 0.75 * np.asarray(clients[1]["a"])
    np.testing.assert_allclose(np.asarray(out["a"]), expect, rtol=1e-5)


# --- sparse comm -----------------------------------------------------------
def test_flatten_roundtrip(rng):
    t = _tree(rng)
    flat = flatten_tree(t)
    back = unflatten_like(flat, t)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(back[key]), np.asarray(t[key]))


def test_sparse_encode_apply_roundtrip(rng):
    base = _tree(rng)
    new = jax.tree.map(lambda x: x + 0.01, base)
    comm = SparseComm(threshold=0.0, use_kernel=False)  # keep everything
    delta, stats = comm.encode(new, base)
    rec = comm.apply(base, delta)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(rec[key]), np.asarray(new[key]),
                                   rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(frac=st.floats(min_value=0.05, max_value=0.9),
       seed=st.integers(min_value=0, max_value=50))
def test_quantile_mode_keeps_requested_fraction(frac, seed):
    rng = jax.random.PRNGKey(seed)
    base = _tree(rng, scale=0.0)
    new = _tree(jax.random.fold_in(rng, 1))
    comm = SparseComm(threshold=f"p{frac}", use_kernel=False)
    _, stats = comm.encode(new, base)
    kept = stats["nnz"] / stats["total"]
    assert abs(kept - frac) < 0.15
    # CSR accounting: value + index per stored element plus the
    # host-tracked row_ptr framing — payload_bytes IS the payload size
    expect = float(stats["nnz"]) * 8 + comm.row_ptr_bytes
    assert abs(comm.payload_bytes - expect) < 1e-6
    assert abs(comm.aco - expect / comm.dense_bytes) < 1e-6


def test_csr_reported_bytes_equal_actual_payload(rng):
    """The acceptance contract of the compacted format: reported
    bytes-on-wire == the byte size of the (values, indices, row_ptr)
    arrays the encode actually produced."""
    from repro.kernels.ref import csr_row_ptr_ref
    comm = SparseComm("p0.2", use_kernel=False)
    new = jax.random.normal(rng, (5, 3000))
    _, stats = comm.encode_batch(new, jnp.zeros_like(new))
    values, indices = stats["values"], stats["indices"]
    stored = np.asarray(stats["nnz"])
    row_ptr = np.asarray(csr_row_ptr_ref(stats["nnz"]))
    # every stored slot is a real (value, index) pair; padding is zeroed
    for k in range(5):
        assert np.count_nonzero(np.asarray(values[k])) <= stored[k]
        assert np.asarray(values[k])[stored[k]:].sum() == 0
    actual = stored.sum() * (4 + 4) + row_ptr.size * 4
    assert comm.payload_bytes == actual
    # paper regime: >50% reduction vs dense at the default p0.2 sparsity
    assert comm.aco < 0.5


def test_deliver_books_at_delivery_not_encode(rng):
    """deliver=False encodes without touching the ledger; passing the
    stats to ``deliver`` later books byte-identically to the inline path,
    and stats that are never delivered (a lost upload) never inflate
    bytes-on-wire."""
    for kwargs in ({"wire_format": "csr"},
                   {"wire_format": "csr_q"},
                   {"wire_format": "csr_q", "q_dtype": "fp16"},
                   {"wire_format": "dense_masked"},
                   {"wire_format": "csr", "enabled": False}):
        inline = SparseComm("p0.2", use_kernel=False, **kwargs)
        deferred = SparseComm("p0.2", use_kernel=False, **kwargs)
        new = jax.random.normal(rng, (4, 2000))
        inline.encode_batch(new, jnp.zeros_like(new))
        _, stats = deferred.encode_batch(new, jnp.zeros_like(new),
                                         deliver=False)
        # nothing booked until delivery
        assert deferred.payload_bytes == 0
        assert deferred.messages == 0 and deferred.dense_bytes == 0
        deferred.deliver(stats)
        assert deferred.payload_bytes == inline.payload_bytes
        assert deferred.messages == inline.messages
        assert deferred.dense_bytes == inline.dense_bytes
        assert deferred.row_ptr_bytes == inline.row_ptr_bytes
        # a second encode whose upload is lost: dropped stats, ledger flat
        before = deferred.payload_bytes
        deferred.encode_batch(new, jnp.zeros_like(new), deliver=False)
        assert deferred.payload_bytes == before

    # the single-message reference path agrees with itself too
    comm = SparseComm("p0.2", use_kernel=False)
    tree = {"w": jax.random.normal(rng, (500,))}
    base = {"w": jnp.zeros(500)}
    _, stats = comm.encode(tree, base, deliver=False)
    assert comm.payload_bytes == 0
    comm.deliver(stats)
    ref = SparseComm("p0.2", use_kernel=False)
    ref.encode(tree, base)
    assert comm.payload_bytes == ref.payload_bytes
    assert comm.messages == ref.messages == 1


def test_wire_breakdown_disabled_reports_dense_component(rng):
    """With sparsification disabled messages are plain dense vectors: the
    breakdown must report them under ``dense_payload_bytes``, not smear
    them across the CSR values/indices components that do not exist."""
    comm = SparseComm("p0.2", use_kernel=False, enabled=False)
    new = _tree(rng)
    base = jax.tree.map(jnp.zeros_like, new)
    _ = comm.encode(new, base)
    n = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(new))
    wb = comm.wire_breakdown()
    assert wb["values_bytes"] == 0.0
    assert wb["indices_bytes"] == 0.0
    assert wb["row_ptr_bytes"] == 0.0
    assert wb["dense_payload_bytes"] == n * 4
    assert wb["payload_bytes"] == n * 4
    # enabled CSR channels report zero dense component
    comm2 = SparseComm("p0.2", use_kernel=False)
    comm2.encode(new, base)
    wb2 = comm2.wire_breakdown()
    assert wb2["dense_payload_bytes"] == 0.0
    assert wb2["values_bytes"] == wb2["indices_bytes"] > 0


def test_wire_breakdown_components_sum_under_every_format(rng):
    """The per-component ledger must be truthful, not a hardcoded split:
    under every wire format the components sum exactly to payload_bytes,
    and each format's structural facts hold (f32 CSR: even values/indices
    split; csr_q int8: values are a third of index bytes and the per-row
    scales appear; fp16: scales are identity and never shipped)."""
    new = jax.random.normal(rng, (6, 3000))
    base = jnp.zeros_like(new)

    def breakdown(**kwargs):
        comm = SparseComm("p0.2", use_kernel=False, **kwargs)
        comm.encode_batch(new, base)
        wb = comm.wire_breakdown()
        comps = (wb["values_bytes"] + wb["indices_bytes"]
                 + wb["scales_bytes"] + wb["row_ptr_bytes"]
                 + wb["dense_payload_bytes"])
        assert abs(comps - wb["payload_bytes"]) < 1e-6, kwargs
        assert wb["payload_bytes"] == comm.payload_bytes
        return comm, wb

    _, wb = breakdown(wire_format="csr")
    assert wb["values_bytes"] == wb["indices_bytes"] > 0
    assert wb["scales_bytes"] == 0.0

    comm_q, wb_q = breakdown(wire_format="csr_q")
    stored = float(wb_q["values_bytes"])          # int8: 1 byte/elem
    nblk = -(-3000 // 512)
    assert wb_q["scales_bytes"] == 4 * 6          # one f32 absmax per row
    # int16 offsets (2 bytes/elem) + the per-row int16 block-count table
    assert wb_q["indices_bytes"] == 2 * stored + 2 * nblk * 6
    assert wb_q["payload_bytes"] < 0.45 * wb["payload_bytes"]

    _, wb_h = breakdown(wire_format="csr_q", q_dtype="fp16")
    assert wb_h["scales_bytes"] == 0.0            # identity, never shipped
    assert wb_h["values_bytes"] == wb_h["indices_bytes"] - 2 * nblk * 6

    _, wb_d = breakdown(wire_format="dense_masked")
    assert wb_d["values_bytes"] == wb_d["indices_bytes"] > 0

    _, wb_off = breakdown(wire_format="csr", enabled=False)
    assert wb_off["dense_payload_bytes"] == wb_off["payload_bytes"] > 0


def test_csr_q_reported_bytes_equal_actual_payload(rng):
    """csr_q acceptance contract: reported bytes-on-wire == the byte size
    of the quantized arrays the encode actually produced (int8 values +
    int16 offsets per stored element, int16 block table + f32 scale per
    row, shared row_ptr)."""
    comm = SparseComm("p0.2", use_kernel=False, wire_format="csr_q")
    new = jax.random.normal(rng, (5, 3000))
    _, stats = comm.encode_batch(new, jnp.zeros_like(new))
    assert stats["values"].dtype == jnp.int8
    assert stats["indices"].dtype == jnp.int16
    assert stats["blocks"].dtype == jnp.int16
    stored = int(np.asarray(stats["nnz"]).sum())
    nblk = -(-3000 // 512)
    actual = (stored * (1 + 2)              # int8 value + int16 offset
              + 5 * (4 + 2 * nblk)          # per-row scale + block table
              + 4 * (5 + 1))                # shared row_ptr
    assert comm.payload_bytes == actual


def test_csr_weighted_scatter_matches_dense_decode(rng):
    from repro.kernels import ref as R
    x = jax.random.normal(rng, (4, 700))
    thr = jnp.full((4,), 0.6, jnp.float32)
    vals, idx, nnz = R.csr_compact2d_ref(x, thr, 700)
    w = jax.random.uniform(jax.random.fold_in(rng, 1), (4,))
    fused = agg.csr_weighted_scatter(vals, idx, w, 700)
    dense = np.einsum("k,kn->n", np.asarray(w),
                      np.asarray(R.csr_decode_ref(vals, idx, 700)))
    np.testing.assert_allclose(np.asarray(fused), dense, rtol=1e-5,
                               atol=1e-6)


def test_blend_flat_csr_matches_dense_blend(rng):
    """The fused scatter-add aggregation == blending the decoded uploads
    through the dense path, to float tolerance."""
    from repro.core import aggregation
    from repro.kernels import ref as R
    K, N = 5, 1200
    base = jax.random.normal(rng, (K, N))
    delta = jax.random.normal(jax.random.fold_in(rng, 1), (K, N))
    server = jax.random.normal(jax.random.fold_in(rng, 2), (N,))
    thr = jnp.full((K,), 0.8, jnp.float32)
    vals, idx, nnz = R.csr_compact2d_ref(delta, thr, N)
    w = jax.random.uniform(jax.random.fold_in(rng, 3), (K,))
    fw = jnp.float32(0.3)
    out = aggregation.blend_flat_csr(server, base, vals, idx, w, fw)
    uploaded = np.asarray(base) + np.asarray(R.csr_decode_ref(vals, idx, N))
    expect = 0.3 * np.asarray(server) + 0.7 * np.einsum(
        "k,kn->n", np.asarray(w), uploaded)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-5,
                               atol=2e-5)
    # kernel path agrees
    out_k = aggregation.blend_flat_csr(server, base, vals, idx, w, fw,
                                       use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_k), expect, rtol=2e-5,
                               atol=2e-5)


def test_blend_flat_csr_q_matches_dequantized_dense_blend(rng):
    """The dequantizing scatter-add aggregation == blending the
    dequantized decoded uploads through the dense path: the fused
    (w * scale) fold introduces no extra error beyond float tolerance."""
    from repro.core import aggregation
    from repro.kernels import ref as R
    K, N = 5, 1200
    base = jax.random.normal(rng, (K, N))
    delta = jax.random.normal(jax.random.fold_in(rng, 1), (K, N))
    server = jax.random.normal(jax.random.fold_in(rng, 2), (N,))
    thr = jnp.full((K,), 0.8, jnp.float32)
    vals, idx, _ = R.csr_compact2d_ref(delta, thr, N)
    _, stored = R.csr_capped_mask_ref(delta, thr, N)
    qvals, scales = R.csr_quantize2d_ref(vals, stored)
    qoffs, qcnt = R.csr_pack_indices_ref(idx, stored, N)
    w = jax.random.uniform(jax.random.fold_in(rng, 3), (K,))
    fw = jnp.float32(0.3)
    out = aggregation.blend_flat_csr_q(server, base, qvals, qoffs, qcnt,
                                       scales, w, fw)
    deq = np.asarray(R.csr_dequantize_ref(qvals, scales))
    abs_idx = np.asarray(R.csr_unpack_indices_ref(qoffs, qcnt))
    decoded = np.zeros((K, N))
    st_np = np.asarray(stored)
    for k in range(K):
        for s in range(st_np[k]):
            decoded[k, abs_idx[k, s]] += deq[k, s]
    uploaded = np.asarray(base) + decoded
    expect = 0.3 * np.asarray(server) + 0.7 * np.einsum(
        "k,kn->n", np.asarray(w), uploaded)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-5,
                               atol=2e-5)


def test_combine_weights_cold_start_explicit():
    """Regression: a participant set whose |D|*g(s) mass is zero (empty
    shards after scaling, or g(s) underflowing for extreme staleness) used
    to normalize to an ALL-ZERO weight vector — the round then silently
    re-broadcast the supervised model scaled by f(r) alone, shrinking the
    global model with no signal. Cold starts must now fall back to an
    explicit uniform weight."""
    g = staleness_fn("exponential")
    # flat: all-zero data sizes -> uniform, not zeros
    w = agg.combine_weights([0, 0, 0], [0, 1, 2], g, None)
    np.testing.assert_allclose(w, [1 / 3] * 3)
    assert abs(w.sum() - 1.0) < 1e-12
    # grouped: one group with zero mass gets uniform within the group and
    # keeps its 1/G share; the live group is unaffected
    groups = np.array([0, 0, 1, 1])
    w = agg.combine_weights([0, 0, 10, 30], [0, 0, 0, 0], g, groups)
    np.testing.assert_allclose(w, [0.25, 0.25, 0.125, 0.375])
    # a normal (warm) case is unchanged by the fix
    w = agg.combine_weights([10, 30], [0, 0], g, None)
    np.testing.assert_allclose(w, [0.25, 0.75])


def test_combine_weights_device_matches_host():
    """The sharded engine's on-device grouped weights == the host path,
    including the cold-start fallback."""
    g = staleness_fn("exponential")
    sizes = [5, 20, 0, 0, 7]
    stal = [0, 1, 0, 2, 3]
    for groups in (np.array([0, 0, 1, 1, 2]), np.array([1, 1, 1, 1, 1]),
                   np.array([0, 1, 0, 1, 0])):
        host = agg.combine_weights(sizes, stal, g, groups)
        size_g = np.asarray(sizes, float) * np.array([g(s) for s in stal])
        dev = agg.combine_weights_device(
            jnp.asarray(size_g, jnp.float32), jnp.asarray(groups),
            int(groups.max()) + 1)
        np.testing.assert_allclose(np.asarray(dev), host, rtol=1e-6,
                                   atol=1e-7)
    # flat twin
    host = agg.combine_weights(sizes, stal, g, None)
    dev = agg.combine_weights_flat_device(
        jnp.asarray(np.asarray(sizes, float)
                    * np.array([g(s) for s in stal]), jnp.float32))
    np.testing.assert_allclose(np.asarray(dev), host, rtol=1e-6, atol=1e-7)
    # device cold start
    dev = agg.combine_weights_flat_device(jnp.zeros(4))
    np.testing.assert_allclose(np.asarray(dev), [0.25] * 4)


def test_kmeans_separates_obvious_clusters():
    pts = np.concatenate([np.zeros((5, 3)), np.ones((5, 3))])
    assign = group_clients(pts, 2)
    assert len(set(assign[:5])) == 1
    assert len(set(assign[5:])) == 1
    assert assign[0] != assign[5]
