"""Aggregation (Eq. 7-10) and sparse-diff communication (§IV-F)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import aggregation as agg
from repro.core.functions import staleness_fn
from repro.core.grouping import group_clients, kmeans
from repro.core.sparse_comm import SparseComm, flatten_tree, unflatten_like


def _tree(rng, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {"a": jax.random.normal(k1, (7, 5)) * scale,
            "b": jax.random.normal(k2, (11,)) * scale}


def test_aggregate_flat_matches_numpy(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(4)]
    server = _tree(jax.random.fold_in(rng, 99))
    sizes = [10, 20, 30, 40]
    stal = [0, 1, 0, 2]
    g = staleness_fn("exponential")
    fw = 0.3
    out = agg.aggregate(server, clients, data_sizes=sizes, stalenesses=stal,
                        g_fn=g, f_weight=fw, groups=None)
    w = np.array(sizes, float) * np.array([g(s) for s in stal])
    w = w / w.sum()
    for key in ("a", "b"):
        expect = fw * np.asarray(server[key]) + (1 - fw) * sum(
            wi * np.asarray(c[key]) for wi, c in zip(w, clients))
        np.testing.assert_allclose(np.asarray(out[key]), expect, rtol=1e-5)


def test_aggregate_single_group_equals_flat(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    server = _tree(jax.random.fold_in(rng, 99))
    kw = dict(data_sizes=[1, 2, 3], stalenesses=[0, 0, 1],
              g_fn=staleness_fn("polynomial"), f_weight=0.4)
    flat = agg.aggregate(server, clients, groups=None, **kw)
    grouped = agg.aggregate(server, clients, groups=np.zeros(3, int), **kw)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(flat[key]),
                                   np.asarray(grouped[key]), rtol=1e-5)


def test_aggregate_kernel_path_matches(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    server = _tree(jax.random.fold_in(rng, 99))
    kw = dict(data_sizes=[5, 5, 5], stalenesses=[0, 1, 2],
              g_fn=staleness_fn("exponential"), f_weight=0.25, groups=None)
    a = agg.aggregate(server, clients, use_kernel=False, **kw)
    b = agg.aggregate(server, clients, use_kernel=True, **kw)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(a[key]), np.asarray(b[key]),
                                   rtol=1e-4, atol=1e-5)


def test_fedavg_weights(rng):
    clients = [_tree(jax.random.fold_in(rng, i)) for i in range(2)]
    out = agg.fedavg(clients, [1, 3])
    expect = 0.25 * np.asarray(clients[0]["a"]) + 0.75 * np.asarray(clients[1]["a"])
    np.testing.assert_allclose(np.asarray(out["a"]), expect, rtol=1e-5)


# --- sparse comm -----------------------------------------------------------
def test_flatten_roundtrip(rng):
    t = _tree(rng)
    flat = flatten_tree(t)
    back = unflatten_like(flat, t)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(back[key]), np.asarray(t[key]))


def test_sparse_encode_apply_roundtrip(rng):
    base = _tree(rng)
    new = jax.tree.map(lambda x: x + 0.01, base)
    comm = SparseComm(threshold=0.0, use_kernel=False)  # keep everything
    delta, stats = comm.encode(new, base)
    rec = comm.apply(base, delta)
    for key in ("a", "b"):
        np.testing.assert_allclose(np.asarray(rec[key]), np.asarray(new[key]),
                                   rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(frac=st.floats(min_value=0.05, max_value=0.9),
       seed=st.integers(min_value=0, max_value=50))
def test_quantile_mode_keeps_requested_fraction(frac, seed):
    rng = jax.random.PRNGKey(seed)
    base = _tree(rng, scale=0.0)
    new = _tree(jax.random.fold_in(rng, 1))
    comm = SparseComm(threshold=f"p{frac}", use_kernel=False)
    _, stats = comm.encode(new, base)
    kept = stats["nnz"] / stats["total"]
    assert abs(kept - frac) < 0.15
    # ACO accounting: payload = 8 bytes/nnz vs 4 dense
    assert abs(comm.aco - 2 * kept) < 1e-6


def test_combine_weights_cold_start_explicit():
    """Regression: a participant set whose |D|*g(s) mass is zero (empty
    shards after scaling, or g(s) underflowing for extreme staleness) used
    to normalize to an ALL-ZERO weight vector — the round then silently
    re-broadcast the supervised model scaled by f(r) alone, shrinking the
    global model with no signal. Cold starts must now fall back to an
    explicit uniform weight."""
    g = staleness_fn("exponential")
    # flat: all-zero data sizes -> uniform, not zeros
    w = agg.combine_weights([0, 0, 0], [0, 1, 2], g, None)
    np.testing.assert_allclose(w, [1 / 3] * 3)
    assert abs(w.sum() - 1.0) < 1e-12
    # grouped: one group with zero mass gets uniform within the group and
    # keeps its 1/G share; the live group is unaffected
    groups = np.array([0, 0, 1, 1])
    w = agg.combine_weights([0, 0, 10, 30], [0, 0, 0, 0], g, groups)
    np.testing.assert_allclose(w, [0.25, 0.25, 0.125, 0.375])
    # a normal (warm) case is unchanged by the fix
    w = agg.combine_weights([10, 30], [0, 0], g, None)
    np.testing.assert_allclose(w, [0.25, 0.75])


def test_combine_weights_device_matches_host():
    """The sharded engine's on-device grouped weights == the host path,
    including the cold-start fallback."""
    g = staleness_fn("exponential")
    sizes = [5, 20, 0, 0, 7]
    stal = [0, 1, 0, 2, 3]
    for groups in (np.array([0, 0, 1, 1, 2]), np.array([1, 1, 1, 1, 1]),
                   np.array([0, 1, 0, 1, 0])):
        host = agg.combine_weights(sizes, stal, g, groups)
        size_g = np.asarray(sizes, float) * np.array([g(s) for s in stal])
        dev = agg.combine_weights_device(
            jnp.asarray(size_g, jnp.float32), jnp.asarray(groups),
            int(groups.max()) + 1)
        np.testing.assert_allclose(np.asarray(dev), host, rtol=1e-6,
                                   atol=1e-7)
    # flat twin
    host = agg.combine_weights(sizes, stal, g, None)
    dev = agg.combine_weights_flat_device(
        jnp.asarray(np.asarray(sizes, float)
                    * np.array([g(s) for s in stal]), jnp.float32))
    np.testing.assert_allclose(np.asarray(dev), host, rtol=1e-6, atol=1e-7)
    # device cold start
    dev = agg.combine_weights_flat_device(jnp.zeros(4))
    np.testing.assert_allclose(np.asarray(dev), [0.25] * 4)


def test_kmeans_separates_obvious_clusters():
    pts = np.concatenate([np.zeros((5, 3)), np.ones((5, 3))])
    assign = group_clients(pts, 2)
    assert len(set(assign[:5])) == 1
    assert len(set(assign[5:])) == 1
    assert assign[0] != assign[5]
