"""SIGKILL chaos: a training subprocess is killed mid-run (possibly
mid-checkpoint-write) and a fresh process restores from whatever survived
on disk, trains the remaining rounds, and must land bit-identical to an
uninterrupted run.

This is the end-to-end crash-consistency pin: the child gets no chance to
flush, close or unwind — torn section files and uncommitted manifests are
expected, and ``find_restorable`` must fall back past them. ``KILL_SEED``
(env, like CHAOS_SEED) varies the kill timing; CI's kill-resume job runs
three seeds.
"""
import dataclasses
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import REFERENCE_CHURN, FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset

TEST_CNN = CNNConfig(name="feds3a-cnn-kill", conv_filters=(8, 8), hidden=16)
CHURN = dataclasses.replace(REFERENCE_CHURN, corrupt_prob=0.15)
TOTAL_ROUNDS = 12

CHILD = """\
import dataclasses, sys
from repro.configs.feds3a_cnn import CNNConfig
from repro.core import REFERENCE_CHURN, FedS3AConfig, FedS3ATrainer
from repro.data import make_dataset

ckpt_dir, progress = sys.argv[1], sys.argv[2]
cnn = CNNConfig(name="feds3a-cnn-kill", conv_filters=(8, 8), hidden=16)
churn = dataclasses.replace(REFERENCE_CHURN, corrupt_prob=0.15)
data = make_dataset("basic", scale=0.0015, seed=0)
tr = FedS3ATrainer(data, FedS3AConfig(
    rounds={total}, cnn=cnn, seed=0, engine="batched",
    error_feedback=True, traffic=churn, round_deadline=700.0,
    quorum_floor=1, checkpoint_dir=ckpt_dir, checkpoint_every=2))
for _ in range({total}):
    tr.train(1)
    with open(progress, "w") as f:
        f.write(str(tr.global_version))
""".format(total=TOTAL_ROUNDS)


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


def _mk(data, ckpt_dir):
    return FedS3ATrainer(data, FedS3AConfig(
        rounds=TOTAL_ROUNDS, cnn=TEST_CNN, seed=0, engine="batched",
        error_feedback=True, traffic=CHURN, round_deadline=700.0,
        quorum_floor=1, checkpoint_dir=ckpt_dir, checkpoint_every=2))


def _trace(tr):
    return [(l.participants, l.forced, l.lost, l.corrupted, l.departed,
             l.rejoined, l.resynced, l.quorum, l.crashes,
             round(l.time, 9)) for l in tr.logs]


def test_sigkill_mid_run_then_restore_is_bit_exact(data, tmp_path):
    seed = int(os.environ.get("KILL_SEED", "0"))
    kill_after = 3 + seed % 5          # rounds the child must survive
    ckpt_dir = str(tmp_path / "ck")
    progress = str(tmp_path / "progress")
    script = str(tmp_path / "child.py")
    with open(script, "w") as f:
        f.write(CHILD)

    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    child = subprocess.Popen([sys.executable, script, ckpt_dir, progress],
                             env=env, stdout=subprocess.DEVNULL,
                             stderr=subprocess.PIPE)
    deadline = time.time() + 600
    seen = 0
    while time.time() < deadline:
        if child.poll() is not None:
            pytest.fail("child exited before the kill: "
                        + child.stderr.read().decode()[-2000:])
        try:
            with open(progress) as f:
                seen = int(f.read() or 0)
        except (FileNotFoundError, ValueError):
            seen = 0
        if seen >= kill_after:
            break
        time.sleep(0.1)
    assert seen >= kill_after, "child made no progress before timeout"
    os.kill(child.pid, signal.SIGKILL)
    child.wait()

    # the uninterrupted reference
    ta = _mk(data, str(tmp_path / "ref"))
    ra = ta.train(TOTAL_ROUNDS)

    # a fresh process-equivalent: restore from whatever survived the kill
    tc = _mk(data, ckpt_dir)
    restored = tc.restore()
    assert restored >= 2, "no checkpoint survived the kill"
    assert restored < TOTAL_ROUNDS, \
        "child finished before the kill; raise kill_after"
    # restored may be odd: the child steps via train(1), and every train()
    # call ends with a final checkpoint of wherever it stopped, between
    # the even-round cadence snapshots
    rc = tc.train(TOTAL_ROUNDS - restored)

    assert np.array_equal(np.asarray(ta._global_flat),
                          np.asarray(tc._global_flat))
    assert ra["aco"] == rc["aco"]
    assert ra["fleet"] == rc["fleet"]
    assert ra["metrics"] == rc["metrics"]
    assert _trace(ta) == _trace(tc)
