"""Property-based kernel tests: hypothesis strategies (or the deterministic
shim in environments without hypothesis) driving the Pallas sparse-delta and
staleness-agg kernels against the pure-jnp oracles in kernels/ref.py.

Covers what the hand-picked sweeps in test_kernels.py do not: random shapes,
block-boundary sizes (N % 512 != 0, including N < 512 and N = multiple ± 1),
degenerate thresholds (0.0 all-pass — where pad columns must NOT count —
and +inf all-drop), per-client quantile thresholds, and shard-invariance of
the per-row quantile encode under a client mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels import ref as R

BLK = 512


def _delta(seed, k, n, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    return x


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=7),
    nblk=st.integers(min_value=0, max_value=3),
    off=st.sampled_from([-1, 0, 1, 17, 255, 511]),
    thr=st.sampled_from([0.0, 0.3, 1.5, np.inf]),
    scale=st.floats(min_value=0.1, max_value=10.0),
)
def test_sparse_delta2d_matches_ref(seed, k, nblk, off, thr, scale):
    n = max(nblk * BLK + off, 1)
    x = _delta(seed, k, n, scale)
    thrs = jnp.full((k,), thr, jnp.float32)
    masked, nnz = ops.sparse_delta_batch(x, thrs)
    rmasked, rnnz = R.sparse_delta2d_ref(x, thrs)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(rmasked))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))
    # degenerate ends: all-pass counts exactly N (pad never counts),
    # all-drop counts zero
    if thr == 0.0:
        assert int(np.asarray(nnz).sum()) == k * n
    if np.isinf(thr):
        assert int(np.asarray(nnz).sum()) == 0
        assert float(jnp.abs(masked).max()) == 0.0


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    nblk=st.integers(min_value=0, max_value=4),
    off=st.sampled_from([-1, 0, 1, 123]),
    thr=st.sampled_from([0.0, 0.7, np.inf]),
)
def test_sparse_delta_1d_matches_ref(seed, nblk, off, thr):
    n = max(nblk * BLK + off, 1)
    x = _delta(seed, 1, n, 1.0)[0]
    masked, nnz = ops.sparse_delta(x, thr)
    rmasked, rnnz = R.sparse_delta_ref(x, thr)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(rmasked))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=6),
    n=st.sampled_from([512, 700, 1024, 2048 + 13]),
    frac=st.floats(min_value=0.05, max_value=0.95),
)
def test_quantile_fused_matches_two_step(seed, k, n, frac):
    """The fused per-shard top-frac encode == per-row sampled quantile fed
    to the plain kernel == the comm layer's vmapped quantile path."""
    from repro.core.sparse_comm import _sampled_quantile_batch
    x = _delta(seed, k, n, 1.0)
    masked, nnz, thr = ops.sparse_delta_topfrac(x, frac)
    thr_comm = _sampled_quantile_batch(x, 1.0 - frac)
    np.testing.assert_allclose(np.asarray(thr), np.asarray(thr_comm),
                               rtol=1e-6)
    rmasked, rnnz = R.sparse_delta2d_ref(x, thr_comm)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(rmasked))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))
    kept = np.asarray(nnz).sum() / (k * n)
    assert abs(kept - frac) < 0.2


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=9),
    nblk=st.integers(min_value=0, max_value=3),
    off=st.sampled_from([-1, 0, 1, 300]),
    wmode=st.sampled_from(["uniform", "zeros", "mixed", "negative"]),
)
def test_staleness_agg_matches_ref(seed, k, nblk, off, wmode):
    n = max(nblk * BLK + off, 1)
    d = _delta(seed, k, n, 2.0)
    if wmode == "uniform":
        w = jnp.full((k,), 1.0 / k)
    elif wmode == "zeros":
        w = jnp.zeros((k,))
    elif wmode == "negative":
        w = -jax.random.uniform(jax.random.PRNGKey(seed + 1), (k,))
    else:
        w = jax.random.uniform(jax.random.PRNGKey(seed + 1), (k,)) * \
            jnp.asarray([i % 2 for i in range(k)], jnp.float32)
    out = ops.staleness_agg(d, w)
    ref = R.staleness_agg_ref(d, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:n]),
                               rtol=1e-5, atol=1e-5)
    if wmode == "zeros":
        assert float(jnp.abs(out).max()) == 0.0


# --- CSR compaction --------------------------------------------------------
def _delta_with_zeros(seed, k, n, zero_frac=0.3):
    """Random deltas with injected exact zeros (they pass degenerate
    thresholds but must never go on the wire)."""
    x = _delta(seed, k, n, 1.0)
    u = jax.random.uniform(jax.random.PRNGKey(seed + 7), (k, n))
    return jnp.where(u < zero_frac, 0.0, x)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=6),
    nblk=st.integers(min_value=0, max_value=3),
    off=st.sampled_from([-1, 0, 1, 17, 255, 511]),
    thr=st.sampled_from([0.0, 0.3, 1.5, np.inf]),
)
def test_csr_compact_roundtrip_matches_masked_oracle(seed, k, nblk, off,
                                                     thr):
    """Full-capacity compact -> decode reproduces the masked-dense oracle
    EXACTLY; kernel and jnp oracle agree elementwise; indices are strictly
    ascending within each stored prefix and padding is zeroed."""
    n = max(nblk * BLK + off, 1)
    x = _delta_with_zeros(seed, k, n)
    thrs = jnp.full((k,), thr, jnp.float32)
    vals, idx, nnz = ops.csr_compact(x, thrs, n)
    rvals, ridx, rnnz = R.csr_compact2d_ref(x, thrs, n)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))
    masked, _ = R.sparse_delta2d_ref(x, thrs)
    decoded = np.asarray(R.csr_decode_ref(vals, idx, n))
    np.testing.assert_array_equal(decoded, np.asarray(masked))
    nnz_h, vals_h, idx_h = (np.asarray(a) for a in (nnz, vals, idx))
    # zeros never stored, even at the all-pass threshold
    expect_nnz = np.count_nonzero(np.asarray(masked), axis=1)
    np.testing.assert_array_equal(nnz_h, expect_nnz)
    for row in range(k):
        s = nnz_h[row]
        assert (np.diff(idx_h[row, :s]) > 0).all()
        assert np.all(vals_h[row, s:] == 0)
        assert np.all(idx_h[row, s:] == 0)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=5),
    n=st.sampled_from([300, 512, 1000, 1537]),
    cap_frac=st.floats(min_value=0.05, max_value=0.8),
)
def test_csr_overflow_spill_invariants(seed, k, n, cap_frac):
    """Capacity overflow keeps the first ``cap`` survivors in column order;
    the spill (masked - decode) is exactly the tail, so decode + spill
    reconstructs the masked oracle bit-for-bit (what the EF residual
    relies on)."""
    cap = max(1, int(cap_frac * n))
    x = _delta_with_zeros(seed, k, n)
    thrs = jnp.full((k,), 0.2, jnp.float32)
    vals, idx, nnz = ops.csr_compact(x, thrs, cap)
    rvals, ridx, rnnz = R.csr_compact2d_ref(x, thrs, cap)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(rnnz))
    masked, _ = R.sparse_delta2d_ref(x, thrs)
    masked = np.asarray(masked)
    decoded = np.asarray(R.csr_decode_ref(vals, idx, n))
    stored = np.minimum(np.asarray(nnz), cap)
    spill = masked - decoded
    for row in range(k):
        kept_cols = np.flatnonzero(masked[row])
        # decode holds exactly the first `stored` kept columns...
        np.testing.assert_array_equal(
            np.flatnonzero(decoded[row]), kept_cols[:stored[row]])
        # ...and the spill is exactly the overflow tail
        np.testing.assert_array_equal(
            np.flatnonzero(spill[row]), kept_cols[stored[row]:])
    np.testing.assert_array_equal(decoded + spill, masked)


# --- csr_q quantization + index packing --------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=6),
    nblk=st.integers(min_value=0, max_value=3),
    off=st.sampled_from([-1, 0, 1, 17, 255, 511]),
    cap_frac=st.floats(min_value=0.1, max_value=1.0),
    q_dtype=st.sampled_from(["int8", "fp16"]),
)
def test_csr_quantize_kernel_matches_ref(seed, k, nblk, off, cap_frac,
                                         q_dtype):
    """Pallas quantize/pack kernel == the jnp oracle elementwise (int8 and
    fp16), index unpack is EXACT on the stored prefixes (in-block offsets +
    block-count table lose nothing), and scales bound the payload: every
    int8 row's absmax quantizes to ±127 exactly."""
    n = max(nblk * BLK + off, 1)
    cap = max(1, int(cap_frac * n))
    x = _delta_with_zeros(seed, k, n)
    thrs = jnp.full((k,), 0.2, jnp.float32)
    vals, idx, nnz = R.csr_compact2d_ref(x, thrs, cap)
    _, stored = R.csr_capped_mask_ref(x, thrs, cap)
    qv, qo, qc, sc = ops.csr_quantize(vals, idx, stored, n, q_dtype=q_dtype)
    rqv, rsc = R.csr_quantize2d_ref(vals, stored, q_dtype=q_dtype)
    rqo, rqc = R.csr_pack_indices_ref(idx, stored, n)
    np.testing.assert_array_equal(np.asarray(qv), np.asarray(rqv))
    np.testing.assert_array_equal(np.asarray(qo), np.asarray(rqo))
    np.testing.assert_array_equal(np.asarray(qc), np.asarray(rqc))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(rsc), rtol=1e-7)
    # index unpack is exact wherever something is stored
    abs_idx = np.asarray(R.csr_unpack_indices_ref(qo, qc))
    st_h, idx_h = np.asarray(stored), np.asarray(idx)
    for row in range(k):
        np.testing.assert_array_equal(abs_idx[row, :st_h[row]],
                                      idx_h[row, :st_h[row]])
    if q_dtype == "int8":
        qv_h, vals_h = np.asarray(qv), np.asarray(vals)
        for row in range(k):
            s = st_h[row]
            if s and np.abs(vals_h[row, :s]).max() > 0:
                assert np.abs(qv_h[row, :s]).max() == 127
        assert np.asarray(sc).min() >= 0


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=5),
    n=st.sampled_from([300, 512, 1000, 1537]),
    cap_frac=st.floats(min_value=0.1, max_value=0.9),
    q_dtype=st.sampled_from(["int8", "fp16"]),
)
def test_csr_q_roundtrip_error_lands_in_residual(seed, k, n, cap_frac,
                                                 q_dtype):
    """The EF contract under csr_q: dequantize(quantize(payload)) scattered
    back + the residual (delta - decoded) reconstructs the raw delta
    EXACTLY — sub-threshold mass, capacity overflow and quantization
    rounding error all land in the residual, nothing is silently lost.
    Also pins the scale-twin identity the engines rely on: quantizing the
    capped-mask dense rows elementwise == scattering the dequantized
    payload."""
    cap = max(1, int(cap_frac * n))
    x = _delta_with_zeros(seed, k, n)
    thrs = jnp.full((k,), 0.2, jnp.float32)
    vals, idx, _ = R.csr_compact2d_ref(x, thrs, cap)
    dense, stored = R.csr_capped_mask_ref(x, thrs, cap)
    qv, sc = R.csr_quantize2d_ref(vals, stored, q_dtype=q_dtype)
    qo, qc = R.csr_pack_indices_ref(idx, stored, n)
    # scatter the dequantized payload
    deq = np.asarray(R.csr_dequantize_ref(qv, sc))
    abs_idx = np.asarray(R.csr_unpack_indices_ref(qo, qc))
    st_h = np.asarray(stored)
    decoded = np.zeros((k, n), np.float32)
    for row in range(k):
        decoded[row, abs_idx[row, :st_h[row]]] = deq[row, :st_h[row]]
    # scale-twin identity: elementwise round-trip of the dense twin is
    # bit-identical to the scattered dequantized payload
    twin = np.asarray(R.quantize_dense_ref(dense, sc, q_dtype=q_dtype))
    np.testing.assert_array_equal(twin, decoded)
    # EF closure: decoded + residual == the raw delta, bit-for-bit
    residual = np.asarray(x) - decoded
    np.testing.assert_array_equal(decoded + residual, np.asarray(x))
    if q_dtype == "int8":
        # quantization error per element is bounded by half a step
        for row in range(k):
            err = np.abs(decoded[row] - np.asarray(dense)[row])
            assert err.max() <= float(sc[row]) * 0.5 + 1e-7


def test_csr_row_ptr():
    nnz = jnp.asarray([3, 0, 5, 1], jnp.int32)
    np.testing.assert_array_equal(np.asarray(R.csr_row_ptr_ref(nnz)),
                                  [0, 3, 3, 8, 9])


# --- chunked parameter axis (ParamLayout + per-chunk encode) ----------------
from repro.core.param_layout import ParamLayout  # noqa: E402


def _template(sizes):
    """Pytree of 1-D leaves with collision-free, order-stable names."""
    return {f"leaf{i:02d}": jax.ShapeDtypeStruct((s,), jnp.float32)
            for i, s in enumerate(sizes)}


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=900),
                   min_size=1, max_size=8),
    chunk_size=st.integers(min_value=64, max_value=700),
)
def test_param_layout_covers_and_aligns(sizes, chunk_size):
    """from_template partitions [0, N) exactly (contiguity is validated by
    the dataclass itself), never exceeds chunk_size, and never lets a chunk
    hold a PART of one leaf plus any piece of another: a chunk either
    contains whole leaves or is wholly inside one oversized (split) leaf."""
    lay = ParamLayout.from_template(_template(sizes), chunk_size)
    assert lay.n == sum(sizes)
    assert lay.bounds[0][0] == 0 and lay.bounds[-1][1] == lay.n
    assert all(e - s <= chunk_size for s, e in lay.bounds)
    edges, off = [], 0
    for s_ in sizes:
        edges.append((off, off + s_))
        off += s_
    for cs, ce in lay.bounds:
        for ls, le in edges:
            if cs < le and ls < ce:           # overlap
                assert (ls >= cs and le <= ce) or (cs >= ls and ce <= le)


@settings(max_examples=25, deadline=None)
@given(
    size=st.integers(min_value=65, max_value=5000),
    chunk_size=st.integers(min_value=64, max_value=512),
)
def test_param_layout_ragged_last_chunk(size, chunk_size):
    """An oversized leaf splits into full-width pieces plus one ragged tail
    of exactly ``size % chunk_size`` (when the leaf doesn't divide)."""
    lay = ParamLayout.from_template(_template([size]), chunk_size)
    widths = lay.sizes
    assert sum(widths) == size
    if size <= chunk_size:
        assert widths == (size,)
    else:
        assert all(w == chunk_size for w in widths[:-1])
        assert widths[-1] == (size % chunk_size or chunk_size)


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=16, max_value=400),
                   min_size=2, max_size=6),
    keep=st.floats(min_value=0.05, max_value=0.35),
)
def test_param_layout_override_never_shares_a_chunk(sizes, keep):
    """A keep_frac override isolates its leaf: every chunk carrying the
    overridden leaf carries ONLY that leaf, and exactly those chunks get
    the per-chunk keep_frac (per-layer sparsity falls out of alignment)."""
    lay = ParamLayout.from_template(_template(sizes), max(sizes) * 2,
                                    overrides={"leaf01": keep})
    hit = 0
    for kf, name in zip(lay.keep_frac, lay.names):
        parts = name.split("+")
        if "leaf01" in parts:
            assert parts == ["leaf01"]
            assert kf == keep
            hit += 1
        else:
            assert kf is None
    assert hit >= 1
    assert lay.describe()["overridden_chunks"] == hit
    assert not lay.is_flat or len(sizes) == 0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=4),
    sizes=st.lists(st.integers(min_value=128, max_value=500),
                   min_size=2, max_size=4),
    keep=st.floats(min_value=0.1, max_value=0.35),
)
def test_chunk_encode_body_matches_per_chunk_oracle(seed, k, sizes, keep):
    """The fused chunked encode == the per-chunk reference pipeline run on
    each slice independently: same stored counts, same decodes, and the
    overridden chunk's kept fraction tracks ITS keep_frac, not the channel
    default — chunk boundaries leak nothing across slices. A ring-gather
    closure base must be bit-identical to the materialized (K, N) base."""
    from repro.core.sparse_comm import SparseComm
    lay = ParamLayout.from_template(_template(sizes), max(sizes),
                                    overrides={"leaf00": keep})
    n = lay.n
    comm = SparseComm("p0.2", use_kernel=False, layout=lay)
    new = _delta(seed, k, n, 1.0)
    base = _delta(seed + 1, k, n, 1.0)
    body = comm.chunk_encode_body(False)
    payloads, stored, decoded = body(new, base)
    delta = new - base
    plan = comm.chunk_plan()
    assert len(payloads) == lay.num_chunks
    for p, st_c, dec in zip(plan, stored, decoded):
        dc = delta[:, p["s"]:p["e"]]
        thr = comm._chunk_thresholds(dc, p["keep"])
        rdense, rstored = R.csr_capped_mask_ref(dc, thr, p["cap"])
        np.testing.assert_array_equal(np.asarray(st_c), np.asarray(rstored))
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(rdense))
        assert int(np.asarray(st_c).max()) <= p["cap"]
        if p["keep"] is not None and p["nc"] >= 128:
            kept = np.asarray(st_c).mean() / p["nc"]
            assert abs(kept - keep) < 0.2
    _, stored2, decoded2 = body(new, lambda s, e: base[:, s:e])
    for a, b in zip(decoded, decoded2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(stored, stored2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1 << 16),
    k=st.integers(min_value=1, max_value=3),
    sizes=st.lists(st.integers(min_value=128, max_value=400),
                   min_size=2, max_size=3),
)
def test_chunk_encode_residual_indices_stay_in_chunk(seed, k, sizes):
    """EF under the layout: the concatenated residual page stores GLOBAL
    column indices and segment c only ever references columns of chunk c
    (value-0 pads land at the chunk start), so the next round's per-chunk
    scatter decode never crosses a boundary. Closure: for each chunk,
    decode + residual-decode == the pre-encode delta wherever the residual
    had room (rfrac caps the tail like the flat path)."""
    from repro.core.sparse_comm import SparseComm
    lay = ParamLayout.from_template(_template(sizes), max(sizes))
    n = lay.n
    comm = SparseComm("p0.2", use_kernel=False, layout=lay)
    rcap = comm.residual_capacity_total()
    new = _delta(seed, k, n, 1.0)
    base = _delta(seed + 1, k, n, 1.0)
    rvals = jnp.zeros((k, rcap), jnp.float32)
    ridx = jnp.zeros((k, rcap), jnp.int32)
    body = comm.chunk_encode_body(True)
    payloads, stored, decoded, (rv2, ri2) = body(new, base, rvals, ridx)
    assert rv2.shape == (k, rcap) and ri2.shape == (k, rcap)
    ri_h, rv_h = np.asarray(ri2), np.asarray(rv2)
    for p in comm.chunk_plan():
        seg_i = ri_h[:, p["roff"]:p["roff"] + p["rcap"]]
        seg_v = rv_h[:, p["roff"]:p["roff"] + p["rcap"]]
        live = seg_v != 0
        assert np.all(seg_i[live] >= p["s"])
        assert np.all(seg_i[live] < p["e"])


# --- shard invariance ------------------------------------------------------
@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a client mesh")
def test_sparse_encode_shard_invariant():
    """Per-row quantile thresholds + masking give the SAME result whether
    the (K, N) stack is encoded whole or row-sharded across the client
    mesh — thresholds are per-row statistics, so shard_map adds no
    cross-device coupling."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.sparse_comm import SparseComm
    from repro.distributed.sharding import CLIENT_AXIS, client_mesh

    mesh = client_mesh()
    D = mesh.devices.size
    core = SparseComm("p0.3", use_kernel=True).batch_core(False)
    K, N = 2 * D, 1000
    new = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    base = jax.random.normal(jax.random.PRNGKey(1), (K, N))

    whole_masked, whole_nnz = core(new, base)
    sharded = jax.jit(shard_map(
        core, mesh=mesh,
        in_specs=(P(CLIENT_AXIS, None), P(CLIENT_AXIS, None)),
        out_specs=(P(CLIENT_AXIS, None), P(CLIENT_AXIS)),
        check_rep=False))
    sh_masked, sh_nnz = sharded(new, base)
    np.testing.assert_allclose(np.asarray(sh_masked),
                               np.asarray(whole_masked), atol=1e-7)
    np.testing.assert_array_equal(np.asarray(sh_nnz), np.asarray(whole_nnz))


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a client mesh")
def test_staleness_agg_psum_matches_whole():
    """blend_flat_sharded's psum-of-local-weighted-sums == the unsharded
    weighted sum, to reduction-order tolerance."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import aggregation as agg
    from repro.distributed.sharding import CLIENT_AXIS, client_mesh

    mesh = client_mesh()
    D = mesh.devices.size
    K, N = 3 * D, 777
    deltas = jax.random.normal(jax.random.PRNGKey(2), (K, N))
    w = jax.random.uniform(jax.random.PRNGKey(3), (K,))
    server = jax.random.normal(jax.random.PRNGKey(4), (N,))
    fw = jnp.float32(0.35)

    def stage(sp, d, wl, f):
        return agg.blend_flat_sharded(sp, d, wl, f, axis_name=CLIENT_AXIS)

    out = jax.jit(shard_map(
        stage, mesh=mesh,
        in_specs=(P(), P(CLIENT_AXIS, None), P(CLIENT_AXIS), P()),
        out_specs=P(), check_rep=False))(server, deltas, w, fw)
    expect = 0.35 * np.asarray(server) + 0.65 * np.einsum(
        "k,kn->n", np.asarray(w), np.asarray(deltas))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-5, atol=2e-5)
