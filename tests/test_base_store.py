"""Versioned base store invariants (staleness-windowed delta chain).

The store replaces every dense per-client base layout with a ring of
``tau + 2`` canonical reconstructions plus one chain delta per round
transition; these tests pin its three contracts:

* same-version clients hold the bit-identical base (a ring lookup, not
  per-client state);
* ``sparse_comm=False`` reproduces the dense store exactly (every chain
  delta is an exact dense copy, so the two stores cannot diverge);
* ring eviction can never drop a version still referenced by an in-flight
  or forced client (the scheduler's tau-forcing invariant guarantees it;
  the store hard-errors if it is ever violated).

Plus the fleet-scale claims: O(tau * N + M) server memory and per-version
broadcast distribution (fewer messages and bytes than the per-target dense
store).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.feds3a_cnn import CNNConfig
from repro.core import FedS3AConfig, FedS3ATrainer
from repro.core.base_store import VersionedBaseStore
from repro.core.sparse_comm import SparseComm, flatten_tree
from repro.data import make_dataset

TEST_CNN = CNNConfig(name="feds3a-cnn-store", conv_filters=(8, 8), hidden=16)


@pytest.fixture(scope="module")
def data():
    return make_dataset("basic", scale=0.0015, seed=0)


# --- store unit behaviour ---------------------------------------------------
def test_ring_slots_and_window():
    flat = jnp.arange(8, dtype=jnp.float32)
    st = VersionedBaseStore(flat, M=4, tau=1)
    assert st.depth == 3
    assert st.version == 0
    np.testing.assert_array_equal(np.asarray(st.gather([0, 2])),
                                  np.asarray(jnp.stack([flat, flat])))
    # advance twice: ring holds versions 0..2 in slots v % 3
    for v in (1, 2):
        st.client_version[:] = max(v - 1, 0)      # everyone keeps up
        st.advance(flat + v, {"stored": 4}, v)
    assert st.version == 2
    assert sorted(st.slot_version.tolist()) == [0, 1, 2]
    np.testing.assert_array_equal(np.asarray(st.latest()),
                                  np.asarray(flat + 2))
    # non-sequential advance is rejected
    with pytest.raises(ValueError):
        st.advance(flat, {"stored": 4}, 4)


def test_ring_eviction_refuses_referenced_version():
    """Advancing over a slot whose version a client still references is a
    staleness-window violation and must hard-error, not corrupt bases."""
    flat = jnp.zeros(4, jnp.float32)
    st = VersionedBaseStore(flat, M=2, tau=0)       # depth 2: slots {0, 1}
    st.client_version[:] = 0                        # both clients at v0
    st.advance(flat + 1, {"stored": 4}, 1)          # slot 1, evicts nothing
    # version 2 would overwrite slot 0 = version 0, still referenced
    with pytest.raises(RuntimeError):
        st.advance(flat + 2, {"stored": 4}, 2)
    # once the stragglers move up, the same advance succeeds
    st.client_version[:] = 1
    st.advance(flat + 2, {"stored": 4}, 2)
    assert st.version == 2


def test_trainer_never_trips_eviction_across_tau(data):
    """End to end, the scheduler's tau-forcing keeps every client inside
    the ring window, so eviction never fires — including tau=0 where every
    round forces all stragglers."""
    for tau in (0, 1, 2):
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=5, seed=0, tau=tau, cnn=TEST_CNN))
        for _ in range(5):
            tr.run_round()                          # raises on violation
        assert (tr.store.version - tr.base_versions <= tau).all()
        assert (tr.base_versions >= 0).all()
        # exactly the re-broadcastable suffix window stays retained
        assert len(tr.store._chain) == min(tau + 1, tr.store.version)


# --- same-version clients share the identical base --------------------------
@pytest.mark.parametrize("engine", ["sequential", "batched", "sharded"])
def test_same_version_clients_share_bitwise_base(data, engine):
    tr = FedS3ATrainer(data, FedS3AConfig(
        rounds=3, seed=0, engine=engine, cnn=TEST_CNN))
    for _ in range(3):
        tr.run_round()
    bases = np.asarray(tr.store.gather(list(range(tr.M))))
    vers = tr.base_versions
    assert len(set(vers)) >= 1
    for v in set(vers):
        rows = bases[vers == v]
        assert (rows == rows[0]).all(), f"version {v} bases diverge"
    # distinct versions hold distinct reconstructions (training moved them)
    if len(set(vers)) > 1:
        v1, v2 = sorted(set(vers))[:2]
        assert not (bases[vers == v1][0] == bases[vers == v2][0]).all()


# --- sparse_comm=False reproduces the dense store exactly --------------------
@pytest.mark.parametrize("engine", ["sequential", "batched", "sharded"])
def test_disabled_sparsification_matches_dense_store_exactly(data, engine):
    """With sparsification off every chain delta is an exact dense copy, so
    R_v == G_v bit-for-bit and the versioned store cannot diverge from the
    dense store — the runs are identical to the last bit."""
    flats = {}
    for store in ("versioned", "dense"):
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=3, seed=0, engine=engine, sparse_comm=False,
            base_store=store, cnn=TEST_CNN))
        tr.train()
        flats[store] = np.asarray(flatten_tree(tr.global_params))
    assert np.array_equal(flats["versioned"], flats["dense"])


# --- fleet-scale claims ------------------------------------------------------
def test_base_store_bytes_sublinear_in_fleet(data):
    """Versioned server memory is O(tau * N + M): bounded by the ring +
    retained chain payloads + the version array — nowhere near the
    O(M * N) dense layouts."""
    tr = FedS3ATrainer(data, FedS3AConfig(rounds=2, seed=0, cnn=TEST_CNN))
    tr.run_round()
    n = int(tr._global_flat.shape[0])
    tau = tr.cfg.tau
    cap = tr.comm.payload_capacity(n)
    # 8 bytes/client version + 1 byte/client detached mask
    bound = (tau + 2) * n * 4 + (tau + 1) * (cap * 8 + 4) + 9 * tr.M + 64
    assert tr.base_store_bytes() <= bound
    dense = FedS3ATrainer(data, FedS3AConfig(
        rounds=2, seed=0, base_store="dense", cnn=TEST_CNN))
    dense.run_round()
    assert tr.base_store_bytes() < dense.base_store_bytes()
    assert dense.base_store_bytes() >= tr.M * n * 4


def test_versioned_distribution_fewer_messages_and_bytes(data):
    """Distribution is a chain-delta broadcast (each transition payload on
    the wire once per round, ≤ tau + 1 of them) instead of one encode per
    target: strictly fewer messages and bytes-on-wire than the dense store
    on the same schedule."""
    runs = {}
    for store in ("versioned", "dense"):
        tr = FedS3ATrainer(data, FedS3AConfig(
            rounds=4, seed=0, base_store=store, cnn=TEST_CNN))
        tr.train()
        runs[store] = tr
    v, d = runs["versioned"], runs["dense"]
    # identical schedules -> identical upload accounting; the delta is all
    # distribution
    assert np.array_equal(v.participation, d.participation)
    assert v.comm.messages < d.comm.messages
    assert v.comm.payload_bytes < d.comm.payload_bytes
    # the store's own ledger counts only the broadcasts
    assert 0 < v.store.dist_payload_bytes() < v.comm.payload_bytes


def test_broadcast_counts_each_transition_once():
    """Targets at several distinct stale versions share one broadcast: the
    round transmits each needed transition payload exactly once (the
    suffix from the stalest target), never once per version group — so the
    payload count is bounded by tau + 1 regardless of target spread."""
    flat = jnp.zeros(16, jnp.float32)
    st = VersionedBaseStore(flat, M=3, tau=2)
    for v in (1, 2, 3):
        st.client_version[:] = v - 1            # keep everyone in-window
        st.advance(flat + v, {"stored": jnp.int32(4)}, v)
    # clients parked at versions 0, 1, 2 with the store at version 3
    st.client_version[:] = np.array([0, 1, 2])
    comm = SparseComm("p0.5", use_kernel=False)
    st.account_distribution(comm, [0, 1, 2])
    # union of suffixes {1,2,3} | {2,3} | {3} = transitions {1, 2, 3}
    assert comm.messages == 3
    assert comm.messages <= st.tau + 1
    assert comm.payload_bytes == 3 * 4 * 8 + 4 * (3 + 1)   # + row_ptr
    assert (st.client_version == 3).all()


def test_versioned_store_rejects_unknown():
    data = make_dataset("basic", scale=0.0015, seed=0)
    with pytest.raises(ValueError):
        FedS3ATrainer(data, FedS3AConfig(base_store="ringbuffer",
                                         cnn=TEST_CNN))


def test_account_distribution_rejects_fresh_target():
    flat = jnp.zeros(4, jnp.float32)
    st = VersionedBaseStore(flat, M=2, tau=1)
    st.advance(flat + 1, {"stored": 4}, 1)
    st.client_version[0] = 1
    comm = SparseComm("p0.5", use_kernel=False, enabled=False)
    with pytest.raises(ValueError):
        st.account_distribution(comm, [0])          # already at version 1
